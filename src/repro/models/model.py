"""Model assembly: embedding -> scan over layer groups -> final norm.

Parameters for each pattern position are stacked over the ``n_groups``
scan dimension (leading "layers" axis), so HLO size is independent of
depth — 64-layer qwen3 compiles as fast as a 4-layer toy. Decode carries
a per-position cache pytree stacked the same way and scanned jointly.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import LayerSpec, ModelConfig
from .blocks import block_apply, block_cache_specs, block_specs
from .common import (
    ParamSpec,
    SpecTree,
    axes_from_specs,
    init_from_specs,
    rms_norm,
    shapes_from_specs,
)

N_AUX = 4  # fixed-size aux vector: [moe_aux_loss, load_balance, router_z, dropped]


def _stack_specs(specs: SpecTree, n: int) -> SpecTree:
    def rec(t):
        if isinstance(t, ParamSpec):
            return ParamSpec((n,) + t.shape, ("layers",) + t.axes,
                             init=t.init, scale=t.scale, dtype=t.dtype)
        return {k: rec(v) for k, v in t.items()}

    return rec(specs)


def model_specs(cfg: ModelConfig) -> SpecTree:
    specs: SpecTree = {}
    Vp = cfg.padded_vocab_size
    if cfg.input_mode != "frames":
        specs["embed"] = ParamSpec((Vp, cfg.d_model), ("vocab", None))
    for i, lspec in enumerate(cfg.pattern):
        specs[f"pos{i}"] = _stack_specs(block_specs(cfg, lspec), cfg.n_groups)
    specs["final_norm"] = ParamSpec((cfg.d_model,), (None,), init="zeros")
    specs["lm_head"] = ParamSpec(
        (cfg.d_model, cfg.n_codebooks * Vp), (None, "vocab"))
    return specs


def init_params(key: jax.Array, cfg: ModelConfig,
                dtype=jnp.float32) -> Dict[str, Any]:
    """Master parameters are f32 (FSDP-sharded); forward casts to the
    compute dtype per step. Pass cfg.dtype for inference-only weights."""
    return init_from_specs(key, model_specs(cfg), jnp.dtype(dtype))


def param_shapes(cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    return shapes_from_specs(model_specs(cfg), jnp.dtype(dtype))


def param_axes(cfg: ModelConfig) -> Dict[str, Any]:
    return axes_from_specs(model_specs(cfg))


def param_count(cfg: ModelConfig) -> int:
    import math

    leaves = jax.tree.leaves(param_shapes(cfg))
    return sum(math.prod(l.shape) for l in leaves)


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top_k + shared experts only;
    padded dead experts never receive tokens)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    n_moe_layers = sum(1 for s in cfg.pattern if s.ffn == "moe") * cfg.n_groups
    per_expert = 3 * cfg.d_model * m.d_expert
    inactive = n_moe_layers * (cfg.padded_n_experts - m.top_k) * per_expert
    return total - inactive


# parameters that stay f32 in compute (routing / SSM dynamics / gate logits)
_KEEP_F32 = ("router", "A_log", "D", "w_if", "b_if", "dt_w", "dt_b")


def _cast(params, dtype):
    def c(path, x):
        name = str(path[-1].key) if path else ""
        if name in _KEEP_F32:
            return x
        if x.dtype in (jnp.float32, jnp.float64) and x.ndim > 1:
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map_with_path(c, params)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def embed_tokens(params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Vocab-sharded embedding lookup.

    Under a sharding context this is a shard_map masked *local* lookup +
    psum_scatter: each model shard gathers the ids it owns and the partial
    rows are reduce-scattered straight into the sequence-parallel layout.
    GSPMD's own lowering of a gather from a vocab-sharded table can
    degenerate into a one-hot dot (measured: ~14x the model's useful
    flops on deepseek prefill_32k), which this path avoids entirely —
    and the backward pass becomes a shard-local scatter-add.
    """
    from ..sharding.rules import _CTX, pspec

    table = params["embed"]
    scale = jnp.sqrt(float(cfg.d_model)).astype(jnp.dtype(cfg.dtype))
    ctx = _CTX.get()
    model_size = ctx[0].shape.get("model", 1) if ctx is not None else 1
    Vp = cfg.padded_vocab_size
    T = tokens.shape[-1]
    if (ctx is None or model_size == 1 or Vp % model_size
            or table.ndim != 2):
        return jnp.take(table, tokens, axis=0).astype(
            jnp.dtype(cfg.dtype)) * scale
    mesh, rules = ctx
    from jax.sharding import PartitionSpec as P

    from ..core.compat import shard_map

    v_shard = Vp // model_size
    scatter_seq = rules.get("act_seq") == "model" and T % model_size == 0

    def local(tab, tok):
        i = jax.lax.axis_index("model")
        lo = i * v_shard
        ids = jnp.clip(tok - lo, 0, v_shard - 1)
        x = jnp.take(tab, ids, axis=0)
        ok = (tok >= lo) & (tok < lo + v_shard)
        x = jnp.where(ok[..., None], x, 0).astype(jnp.dtype(cfg.dtype))
        if scatter_seq:
            return jax.lax.psum_scatter(x, "model", scatter_dimension=1,
                                        tiled=True)
        return jax.lax.psum(x, "model")

    batch_ax = rules.get("batch")
    tok_spec = P(batch_ax, None)
    out_spec = P(batch_ax, "model" if scatter_seq else None, None)
    x = shard_map(
        local, mesh=mesh,
        in_specs=(pspec(("vocab", None), rules), tok_spec),
        out_specs=out_spec,
    )(table, tokens)
    return x * scale


def _aux_vector(aux: Dict[str, jax.Array]) -> jax.Array:
    keys = ("moe_aux_loss", "moe_load_balance", "moe_router_z",
            "moe_dropped_frac")
    return jnp.stack([jnp.float32(aux.get(k, 0.0)) for k in keys])


def forward(
    params: Dict[str, Any],
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    mode: str = "train",                  # train | prefill
) -> Tuple[jax.Array, jax.Array, Optional[Dict[str, Any]]]:
    """Returns (hidden (B,T,E), aux_vec (N_AUX,), caches_or_None)."""
    compute_params = _cast(params, jnp.dtype(cfg.dtype))
    if cfg.input_mode == "frames":
        x = batch["frames"].astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_tokens(compute_params, batch["tokens"], cfg)
    enc = batch.get("encoder_embeddings")
    if enc is not None:
        enc = enc.astype(jnp.dtype(cfg.dtype))
    B, T = x.shape[0], x.shape[1]
    positions = jnp.arange(T, dtype=jnp.int32)
    stacked = {f"pos{i}": compute_params[f"pos{i}"]
               for i in range(len(cfg.pattern))}
    # sequence-parallel residual stream: the scan carry (and thus the
    # per-group saved activation) lives sharded over the model axis
    from ..sharding.rules import constrain, grad_constrained

    x = constrain(x, ("batch", "act_seq", None))
    # per-group parameter cotangents reduce-scatter straight to the
    # parameter sharding (axes minus the leading scan/"layers" dim)
    sliced_axes = {
        k: jax.tree.map(lambda ax: tuple(ax[1:]), param_axes(cfg)[k],
                        is_leaf=lambda t: isinstance(t, tuple))
        for k in stacked
    }

    def _constrain_grads(tree, axes_tree):
        leaves, treedef = jax.tree.flatten(tree)
        axes = jax.tree.flatten(
            axes_tree, is_leaf=lambda t: isinstance(t, tuple))[0]
        return jax.tree.unflatten(
            treedef,
            [grad_constrained(a, ax) for a, ax in zip(leaves, axes)])

    def group_body(x, gparams):
        if mode == "train":
            gparams = {k: _constrain_grads(gparams[k], sliced_axes[k])
                       for k in gparams}
        aux_acc = jnp.zeros((N_AUX,), jnp.float32)
        caches = {}
        for i, lspec in enumerate(cfg.pattern):
            x, nc, aux = block_apply(
                gparams[f"pos{i}"], x, cfg, lspec, positions,
                enc=enc, mode=mode)
            caches[f"pos{i}"] = nc or {}
            if aux:
                aux_acc = aux_acc + _aux_vector(aux)
        x = constrain(x, ("batch", "act_seq", None))
        if mode == "prefill":
            return x, (aux_acc, caches)
        return x, aux_acc

    body = _remat(group_body, cfg.remat if mode == "train" else "none")
    if mode == "prefill":
        x, (aux_all, caches) = jax.lax.scan(body, x, stacked)
        aux = aux_all.sum(0)
    else:
        x, aux_all = jax.lax.scan(body, x, stacked)
        aux = aux_all.sum(0)
        caches = None
    x = rms_norm(x, compute_params["final_norm"], cfg.norm_eps)
    return x, aux, caches


def decode_step(
    params: Dict[str, Any],
    caches: Dict[str, Any],
    batch: Dict[str, jax.Array],          # tokens (B,1) or frames (B,1,E)
    pos: jax.Array,                       # scalar int32 current position
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step. Returns (logits (B, ncb, V), new caches)."""
    compute_params = _cast(params, jnp.dtype(cfg.dtype))
    if cfg.input_mode == "frames":
        x = batch["frames"].astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_tokens(compute_params, batch["tokens"], cfg)
    stacked = {f"pos{i}": compute_params[f"pos{i}"]
               for i in range(len(cfg.pattern))}

    def group_body(x, xs):
        gparams, gcache = xs
        new_caches = {}
        for i, lspec in enumerate(cfg.pattern):
            x, nc, _ = block_apply(
                gparams[f"pos{i}"], x, cfg, lspec, pos,
                cache=gcache[f"pos{i}"], mode="decode")
            new_caches[f"pos{i}"] = nc or {}
        return x, new_caches

    x, new_caches = jax.lax.scan(group_body, x, (stacked, caches))
    x = rms_norm(x, compute_params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ compute_params["lm_head"]).astype(jnp.float32)
    B = logits.shape[0]
    logits = logits.reshape(B, cfg.n_codebooks, cfg.padded_vocab_size)
    return mask_pad_logits(logits, cfg), new_caches


def mask_pad_logits(logits: jax.Array, cfg: ModelConfig) -> jax.Array:
    """-inf the padded vocab tail so sampling/argmax never picks it."""
    Vp = cfg.padded_vocab_size
    if Vp == cfg.vocab_size:
        return logits
    valid = jnp.arange(Vp) < cfg.vocab_size
    return jnp.where(valid, logits, -1e30)


def init_cache_shapes(
    cfg: ModelConfig, batch: int, seq_len: int
) -> Dict[str, Any]:
    """Abstract stacked cache pytree for decode dry-runs/serving."""
    out: Dict[str, Any] = {}
    for i, lspec in enumerate(cfg.pattern):
        sub = block_cache_specs(cfg, lspec, batch, seq_len)

        def stack(t):
            if isinstance(t, dict):
                return {k: stack(v) for k, v in t.items()}
            return jax.ShapeDtypeStruct((cfg.n_groups,) + t.shape, t.dtype)

        out[f"pos{i}"] = stack(sub)
    return out


def init_cache_zeros(cfg: ModelConfig, batch: int, seq_len: int):
    """Concrete zero caches; attention position slots start at -1 so the
    decode mask treats them as empty."""
    shapes = init_cache_shapes(cfg, batch, seq_len)

    def mk(path, t):
        if path and getattr(path[-1], "key", None) == "pos":
            return jnp.full(t.shape, -1, jnp.int32)
        return jnp.zeros(t.shape, t.dtype)

    return jax.tree_util.tree_map_with_path(mk, shapes)
