"""Attention: GQA + RoPE (+ qk-norm, sliding windows, cross-attention).

Three interchangeable implementations:

  * ``naive``     — materializes (T, S) scores; only for small shapes/tests.
  * ``blockwise`` — two-level scan over (q-block, kv-block) with running
                    softmax (flash-style) in pure jnp. Memory O(block^2),
                    differentiable, compiles on any backend; this is what
                    dry-runs lower. Causal masking is applied per block; the
                    dense band wastes ~2x flops on fully-masked blocks for
                    global causal layers — a known, *measured* inefficiency
                    that the roofline 'useful flops' ratio surfaces and the
                    §Perf hillclimb attacks. Sliding-window layers use a
                    static band (no waste beyond edge blocks).
  * ``pallas``    — the TPU kernel in repro.kernels.flash_attention (same
                    math, MXU-aligned BlockSpec tiling), validated against
                    these references in interpret mode.

Decode attends one query against a (possibly ring-buffered) KV cache.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import LayerSpec, ModelConfig
from .common import ParamSpec, apply_rope, rms_norm, softcap

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamSpec]:
    E, H, K, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((E, H * D), ("embed", "heads")),
        "wk": ParamSpec((E, K * D), ("embed", "kv_heads")),
        "wv": ParamSpec((E, K * D), ("embed", "kv_heads")),
        "wo": ParamSpec((H * D, E), ("heads", "embed"), init="scaled", scale=1.0),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((D,), (None,), init="zeros")
        specs["k_norm"] = ParamSpec((D,), (None,), init="zeros")
    return specs


# ---------------------------------------------------------------------------
# reference (naive) attention
# ---------------------------------------------------------------------------

def naive_attention(
    q: jax.Array,                      # (B, T, K, G, D)
    k: jax.Array,                      # (B, S, K, D)
    v: jax.Array,                      # (B, S, K, D)
    pos_q: jax.Array,                  # (T,)
    pos_k: jax.Array,                  # (S,)
    causal: bool = True,
    window: Optional[int] = None,
    cap: Optional[float] = None,
) -> jax.Array:
    D = q.shape[-1]
    scores = jnp.einsum("btkgd,bskd->bkgts", q, k).astype(jnp.float32)
    scores = softcap(scores / math.sqrt(D), cap)
    mask = jnp.ones((pos_q.shape[0], pos_k.shape[0]), bool)
    if causal:
        mask &= pos_k[None, :] <= pos_q[:, None]
    if window is not None:
        mask &= pos_k[None, :] > pos_q[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p.astype(v.dtype), v)
    return out


# ---------------------------------------------------------------------------
# blockwise (flash-style, pure jnp)
# ---------------------------------------------------------------------------

def _pad_to(x: jax.Array, size: int, axis: int) -> jax.Array:
    pad = -x.shape[axis] % size
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def blockwise_attention(
    q: jax.Array,                      # (B, T, K, G, D)
    k: jax.Array,                      # (B, S, K, D)
    v: jax.Array,                      # (B, S, K, D)
    pos_q: jax.Array,                  # (T,) int32
    pos_k: jax.Array,                  # (S,) int32
    causal: bool = True,
    window: Optional[int] = None,
    block: int = 512,
    cap: Optional[float] = None,
) -> jax.Array:
    B, T, K, G, D = q.shape
    S = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    bq = min(block, T)
    bk = min(block, S)
    qp = _pad_to(q, bq, 1)
    kp = _pad_to(k, bk, 1)
    vp = _pad_to(v, bk, 1)
    pqp = _pad_to(pos_q, bq, 0)
    pkp = _pad_to(pos_k, bk, 0) + jnp.where(
        jnp.arange(pkp_len := (S + (-S % bk))) < S, 0, -(10**9)
    )  # padded kv positions become very negative -> always masked
    Nq = qp.shape[1] // bq
    Nk = kp.shape[1] // bk
    # band width in kv blocks per q block
    if not causal:
        nband = Nk
    elif window is not None:
        nband = min(Nk, window // bk + 2)
    else:
        nband = Nk

    qb = qp.reshape(B, Nq, bq, K, G, D)
    kb = kp.reshape(B, Nk, bk, K, D)
    vb = vp.reshape(B, Nk, bk, K, D)
    pqb = pqp.reshape(Nq, bq)
    pkb = pkp.reshape(Nk, bk)

    def q_block(i, q_i, pq_i):
        # q_i: (B, bq, K, G, D)
        def kv_step(carry, b):
            acc, m, l = carry
            j_raw = (i - (nband - 1) + b) if causal else b
            j = jnp.clip(j_raw, 0, Nk - 1)
            k_j = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
            pk_j = jax.lax.dynamic_index_in_dim(pkb, j, 0, keepdims=False)
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_i, k_j).astype(jnp.float32)
            s = softcap(s * scale, cap)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= pk_j[None, :] <= pq_i[:, None]
                mask &= j_raw >= 0
            if window is not None:
                mask &= pk_j[None, :] > pq_i[:, None] - window
            mask &= pk_j[None, :] >= 0
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, v_j.astype(jnp.float32)
            )
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, K, G, bq, D), jnp.float32)
        m0 = jnp.full((B, K, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, bq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), jnp.arange(nband)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)       # (B, K, G, bq, D)

    def outer(carry, xs):
        i, q_i, pq_i = xs
        return carry, q_block(i, q_i, pq_i)

    _, outs = jax.lax.scan(
        outer, None, (jnp.arange(Nq), jnp.moveaxis(qb, 1, 0), pqb)
    )
    # outs: (Nq, B, K, G, bq, D) -> (B, T, K, G, D)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 3, 4, 1, 5, 2)
    # currently (B, ... ) — reorder explicitly:
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Nq * bq, K, G, D)
    return out[:, :T]


# ---------------------------------------------------------------------------
# decode attention (one query position against a cache)
# ---------------------------------------------------------------------------

def decode_attention(
    q: jax.Array,                      # (B, 1, K, G, D)
    k_cache: jax.Array,                # (B, S, K, D)
    v_cache: jax.Array,                # (B, S, K, D)
    pos_k: jax.Array,                  # (S,) positions held in each slot
    pos_q: jax.Array,                  # scalar int32 current position
    window: Optional[int] = None,
    cap: Optional[float] = None,
) -> jax.Array:
    D = q.shape[-1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k_cache).astype(jnp.float32)
    s = softcap(s / math.sqrt(D), cap)
    valid = (pos_k >= 0) & (pos_k <= pos_q)
    if window is not None:
        valid &= pos_k > pos_q - window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache)
    return out


# ---------------------------------------------------------------------------
# full attention sublayer
# ---------------------------------------------------------------------------

def _split_heads(x, B, T, n, D):
    return x.reshape(B, T, n, D)


def attn_apply(
    params: Dict[str, jax.Array],
    x: jax.Array,                      # (B, T, E)
    cfg: ModelConfig,
    spec: LayerSpec,
    positions: jax.Array,              # (T,) or scalar for decode
    cache: Optional[Dict[str, jax.Array]] = None,
    mode: str = "train",               # train | prefill | decode
    impl: Optional[str] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self-attention sublayer. Returns (out, new_cache)."""
    B, T, E = x.shape
    H, K, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // K
    impl = impl or cfg.attn_impl
    window = spec.window

    q = (x @ params["wq"]).reshape(B, T, H, D)
    k = (x @ params["wk"]).reshape(B, T, K, D)
    v = (x @ params["wv"]).reshape(B, T, K, D)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    if mode == "decode":
        pos_q = positions                      # scalar
        q = apply_rope(q, pos_q[None].astype(jnp.int32), cfg.rope_theta)
        k = apply_rope(k, pos_q[None].astype(jnp.int32), cfg.rope_theta)
        assert cache is not None
        S = cache["k"].shape[1]
        slot = (pos_q % S) if window is not None else jnp.minimum(pos_q, S - 1)
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1)
        pos_k = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], pos_q[None].astype(jnp.int32), slot, 0
        )
        qh = q.reshape(B, 1, K, G, D)
        out = decode_attention(qh, k_cache, v_cache, pos_k, pos_q,
                               window=window, cap=None)
        out = out.reshape(B, 1, H * D) @ params["wo"]
        return out, {"k": k_cache, "v": v_cache, "pos": pos_k}

    pos = positions.astype(jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    # Megatron-SP boundary: gather the sequence dim once here (heads go to
    # the model axis instead). Without this, the blockwise kv indexing on
    # an act_seq-sharded tensor makes GSPMD emit a collective *per block
    # step* (measured: 92k collectives/step on qwen3 train_4k).
    from ..sharding.rules import constrain

    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    qh = q.reshape(B, T, K, G, D)
    with jax.named_scope("vmem_fused_attention"):
        if impl == "naive" or T <= cfg.attn_block:
            out = naive_attention(qh, k, v, pos, pos, causal=True,
                                  window=window)
        else:
            out = blockwise_attention(qh, k, v, pos, pos, causal=True,
                                      window=window, block=cfg.attn_block)
    out = constrain(out.reshape(B, T, H, D), ("batch", None, "heads", None))
    out = out.reshape(B, T, H * D) @ params["wo"]

    new_cache = None
    if mode == "prefill":
        S = min(T, window) if window is not None else T
        if window is not None:
            # ring buffer holds the last `window` positions, slot = pos % S
            idx = (pos[-S:] % S)
            k_keep, v_keep, p_keep = k[:, -S:], v[:, -S:], pos[-S:]
            order = jnp.argsort(idx)
            new_cache = {
                "k": k_keep[:, order],
                "v": v_keep[:, order],
                "pos": p_keep[order],
            }
        else:
            new_cache = {"k": k, "v": v, "pos": pos}
    return out, new_cache


def cache_specs(
    cfg: ModelConfig, spec: LayerSpec, batch: int, seq_len: int
) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract KV-cache entry for one attention sublayer."""
    K, D = cfg.n_kv_heads, cfg.head_dim
    S = min(seq_len, spec.window) if spec.window is not None else seq_len
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jax.ShapeDtypeStruct((batch, S, K, D), dt),
        "v": jax.ShapeDtypeStruct((batch, S, K, D), dt),
        "pos": jax.ShapeDtypeStruct((S,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# cross-attention sublayer (vlm): kv from precomputed encoder embeddings
# ---------------------------------------------------------------------------

def cross_attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    base = attn_specs(cfg)
    base["gate"] = ParamSpec((), (), init="zeros")   # gated cross-attn (llama3.2)
    return base


def cross_attn_apply(
    params: Dict[str, jax.Array],
    x: jax.Array,                      # (B, T, E)
    enc: jax.Array,                    # (B, N, E) precomputed patch embeddings
    cfg: ModelConfig,
) -> jax.Array:
    B, T, E = x.shape
    N = enc.shape[1]
    H, K, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // K
    q = (x @ params["wq"]).reshape(B, T, K, G, D)
    k = (enc @ params["wk"]).reshape(B, N, K, D)
    v = (enc @ params["wv"]).reshape(B, N, K, D)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    pos_q = jnp.arange(T, dtype=jnp.int32)
    pos_k = jnp.arange(N, dtype=jnp.int32)
    if max(T, N) <= cfg.attn_block:
        out = naive_attention(q, k, v, pos_q, pos_k, causal=False)
    else:
        out = blockwise_attention(q, k, v, pos_q, pos_k, causal=False,
                                  block=cfg.attn_block)
    out = out.reshape(B, T, H * D) @ params["wo"]
    return jnp.tanh(params["gate"]).astype(out.dtype) * out
