"""Mixture-of-Experts FFN: top-k routing, capacity-bounded dispatch.

Dispatch uses gather/scatter (sort-free GShard-style slots) instead of the
classic (tokens, experts, capacity) one-hot einsum: the one-hot form adds
O(T*E*C*d) dispatch flops (it *doubles* MoE compute for fine-grained
configs like deepseek-64e); gathers add none. Tokens are processed in
groups (scan) to bound the (experts, capacity, d_model) working set.

Expert weights carry the "experts" logical axis -> sharded over the
"model" mesh axis (expert parallelism). Under GSPMD the gathers lower to
all-to-all-ish collectives; the explicit shard_map EP path in
repro.comm is the §Perf alternative.

Shared experts (deepseek) are a dense MLP added to every token's output.
Aux losses: switch load-balance + router z-loss, returned for logging.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import ParamSpec, activation


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    m = cfg.moe
    E, F = cfg.d_model, m.d_expert
    Ne = cfg.padded_n_experts       # dead pad experts: router masks them
    specs = {
        "router": ParamSpec((E, Ne), ("embed", "experts"), dtype=jnp.float32),
        "wg": ParamSpec((Ne, E, F), ("experts", "embed", "expert_mlp")),
        "wi": ParamSpec((Ne, E, F), ("experts", "embed", "expert_mlp")),
        "wo": ParamSpec((Ne, F, E), ("experts", "expert_mlp", "embed"),
                        init="scaled", scale=1.0),
    }
    if m.n_shared:
        Fs = F * m.n_shared
        specs["shared_wg"] = ParamSpec((E, Fs), ("embed", "mlp"))
        specs["shared_wi"] = ParamSpec((E, Fs), ("embed", "mlp"))
        specs["shared_wo"] = ParamSpec((Fs, E), ("mlp", "embed"),
                                       init="scaled", scale=1.0)
    return specs


def _route(logits: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array]:
    """(gates, indices): softmax over the selected top-k (renormalized)."""
    vals, idx = jax.lax.top_k(logits, top_k)          # (T, k)
    gates = jax.nn.softmax(vals, axis=-1)
    return gates, idx


def _group_capacity(group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = math.ceil(group * m.top_k / m.n_experts * m.capacity_factor)
    return max(m.top_k, min(group, -(-c // 4) * 4))   # mult of 4, sane bounds


def moe_apply(
    params: Dict[str, jax.Array],
    x: jax.Array,                                     # (B, T, E)
    cfg: ModelConfig,
    token_group: int = 4096,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    m = cfg.moe
    act = activation(cfg.act)
    B, T, E = x.shape
    Ne, k = cfg.padded_n_experts, m.top_k
    n_real = m.n_experts
    # SP boundary: token grouping slices the (batch*time) dim, so the
    # sequence must be gathered here (expert dim carries the model axis)
    from ..sharding.rules import constrain

    x = constrain(x, ("batch", None, None))
    flat = x.reshape(B * T, E)
    n_tok = flat.shape[0]
    group = min(token_group, n_tok)
    pad = -n_tok % group
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    n_groups = flat.shape[0] // group
    C = _group_capacity(group, cfg)
    xg = flat.reshape(n_groups, group, E)

    def one_group(carry, xt):                         # xt: (group, E)
        logits = (xt.astype(jnp.float32) @ params["router"])   # (g, Ne)
        if Ne != n_real:
            logits = jnp.where(jnp.arange(Ne) < n_real, logits, -1e30)
        gates, idx = _route(logits, k)                # (g, k)
        # position of each (token, choice) inside its expert
        onehot = jax.nn.one_hot(idx, Ne, dtype=jnp.int32)       # (g, k, Ne)
        flat_oh = onehot.reshape(group * k, Ne)
        pos = jnp.cumsum(flat_oh, axis=0) - flat_oh             # exclusive
        pos = (pos * flat_oh).sum(-1).reshape(group, k)         # (g, k)
        keep = pos < C
        # scatter token ids into (Ne, C) slots; empty slots point to a
        # zero row (index `group`, provided by padding xt below)
        slot_tok = jnp.full((Ne, C), group, jnp.int32)
        e_idx = idx.reshape(-1)
        c_idx = jnp.where(keep, pos, C).reshape(-1)   # dropped -> col C (oob)
        tok_id = jnp.tile(jnp.arange(group)[:, None], (1, k)).reshape(-1)
        slot_tok = slot_tok.at[e_idx, jnp.minimum(c_idx, C - 1)].set(
            jnp.where(c_idx < C, tok_id, group), mode="drop"
        )
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, E), xt.dtype)], 0)
        xe = xt_pad[slot_tok]                          # (Ne, C, E)
        h = act(jnp.einsum("ecd,edf->ecf", xe, params["wg"])) * jnp.einsum(
            "ecd,edf->ecf", xe, params["wi"]
        )
        ye = jnp.einsum("ecf,efd->ecd", h, params["wo"])        # (Ne, C, E)
        # gather back per (token, choice)
        safe_pos = jnp.minimum(pos, C - 1)
        out_pair = ye[idx, safe_pos]                   # (g, k, E)
        w = (gates * keep).astype(ye.dtype)
        yt = jnp.einsum("gk,gke->ge", w, out_pair)
        # aux stats
        frac_tokens = flat_oh.reshape(group, k, Ne).sum((0, 1)) / (group * k)
        probs = jax.nn.softmax(logits, axis=-1).mean(0)
        lb = (frac_tokens * probs).sum() * n_real
        z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        dropped = 1.0 - keep.mean()
        return carry, (yt, jnp.stack([lb, z, dropped]))

    _, (y, stats) = jax.lax.scan(one_group, None, xg)
    y = y.reshape(-1, E)[:n_tok].reshape(B, T, E)
    lb, z, dropped = jnp.mean(stats, axis=0)
    if m.n_shared:
        hs = act(flat[:n_tok] @ params["shared_wg"]) * (
            flat[:n_tok] @ params["shared_wi"]
        )
        y = y + (hs @ params["shared_wo"]).reshape(B, T, E)
    aux = {
        "moe_load_balance": lb,
        "moe_router_z": z,
        "moe_dropped_frac": dropped,
        "moe_aux_loss": m.router_aux_weight * lb + m.router_z_weight * z,
    }
    return y, aux
