"""JSONL trace writer/reader (the persistence layer of ``repro.trace``).

The writer is the ``emit(dict)`` sink the instrumented layers speak
(:class:`repro.match.MatchEngine`, :class:`repro.match.Fabric`,
:class:`repro.comm.progress.ProgressEngine`): one compact JSON object per
line, header first, ``.gz`` transparently compressed like
:mod:`repro.core.timeline`. ``emit`` is serialized by a lock because the
progress engine writes from two threads.
"""
from __future__ import annotations

import gzip
import json
import threading
import time
from typing import Dict, List, Optional, Tuple

from ..core.counters import CounterRegistry
from .schema import (TraceSchemaError, make_header, validate_header,
                     validate_record)

# record types that carry live wall-clock timing in schema v2
_TIMED = ("post", "arr", "pe")


def _open(path: str, write: bool):
    if path.endswith(".gz"):
        return gzip.open(path, "wt" if write else "rt")
    return open(path, "w" if write else "r")


class TraceWriter:
    """Append-only trace sink with a versioned header.

    Usable as a context manager; ``close`` is idempotent. ``n_records``
    counts everything written including the header.

    With ``wall_clock=True`` (the default) every engine-op / progress
    record is stamped with ``t_wall``, nanoseconds since the writer
    opened (schema v2), so replays can report measured time dilation.
    ``wall_clock=False`` is deterministic mode: no ``t_wall`` stamps and
    counter snapshots exclude measured-time (``*_ns``) statistics, so
    the same op stream produces a byte-identical trace file — the
    property the workload scenario suite's determinism tests pin down.
    """

    def __init__(self, path: str, mode: str = "binned",
                 meta: Optional[Dict] = None, wall_clock: bool = True):
        self.path = str(path)
        self.wall_clock = wall_clock
        self._lock = threading.Lock()
        self._f = _open(self.path, write=True)
        self.n_records = 0
        self._t0 = time.perf_counter_ns()
        self._emit_unlocked(make_header(mode, meta))

    def _emit_unlocked(self, rec: Dict) -> None:
        self._f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self.n_records += 1

    def emit(self, rec: Dict) -> None:
        if (self.wall_clock and rec.get("t") in _TIMED
                and "t_wall" not in rec):
            rec = dict(rec, t_wall=time.perf_counter_ns() - self._t0)
        with self._lock:
            if self._f is None:
                raise ValueError(f"trace {self.path} is closed")
            self._emit_unlocked(rec)

    def snapshot(self, registry: CounterRegistry) -> None:
        """Write the registry's per-lane counter statistics as a ``snap``
        record (drains, so the snapshot reflects everything recorded so
        far; lane pids key the stats). In deterministic mode the
        wall-clock-measured ``*_ns`` statistics are dropped — they are
        the only nondeterministic content of a snapshot."""
        lanes = registry.drain_lanes()
        stats = {str(pid): {name: st.to_attrs()
                            for name, st in sorted(per.items())
                            if self.wall_clock or not name.endswith("_ns")}
                 for pid, per in sorted(lanes.items())}
        self.emit({"t": "snap", "stats": stats})

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_trace(path: str) -> Tuple[Dict, List[Dict]]:
    """Load and validate a trace: returns ``(header, records)``. Raises
    :class:`repro.trace.schema.TraceSchemaError` on a version or shape
    mismatch — the schema gate ``scripts/verify.sh`` exercises."""
    header: Optional[Dict] = None
    records: List[Dict] = []
    with _open(str(path), write=False) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if header is None:
                header = validate_header(rec)
            else:
                records.append(validate_record(rec))
    if header is None:
        raise TraceSchemaError(f"empty trace file (no header): {path}")
    return header, records
