"""JSONL trace writer/reader (the persistence layer of ``repro.trace``).

The writer is the ``emit(dict)`` sink the instrumented layers speak
(:class:`repro.match.MatchEngine`, :class:`repro.match.Fabric`,
:class:`repro.comm.progress.ProgressEngine`): one compact JSON object per
line, header first, ``.gz`` transparently compressed like
:mod:`repro.core.timeline`.

Emission is buffered: records accumulate in a per-writer list and are
serialized in batches — one lock acquisition, one ``"\\n".join`` of the
batch, one file write — so the per-record hot-path cost is a wall-clock
stamp and a list append under a briefly-held lock (the progress engine
writes from two threads). ``flush`` forces the buffer to disk;
``close`` flushes and is idempotent. Batch boundaries are invisible in
the output: the file bytes are identical to an unbuffered writer's.
"""
from __future__ import annotations

import gzip
import json
import threading
import time
from typing import Dict, List, Optional, Tuple

from ..core.counters import CounterRegistry
from .schema import (TraceSchemaError, make_header, validate_header,
                     validate_record)

# record types that carry live wall-clock timing in schema v2
_TIMED = ("post", "arr", "pe")

# records buffered between batch serializations (a batch is ~100 bytes
# per record, so the default keeps ~25 KiB in flight)
BUFFER_RECORDS = 256

# one shared encoder: json.dumps(..., separators=...) builds a fresh
# JSONEncoder per call, which is pure overhead at trace volume
_encode = json.JSONEncoder(separators=(",", ":")).encode


def _open(path: str, write: bool):
    if path.endswith(".gz"):
        return gzip.open(path, "wt" if write else "rt")
    return open(path, "w" if write else "r")


class TraceWriter:
    """Append-only trace sink with a versioned header.

    Usable as a context manager; ``close`` is idempotent. ``n_records``
    counts everything emitted including the header (buffered records
    included — they are on disk after ``flush``/``close``).

    With ``wall_clock=True`` (the default) every engine-op / progress
    record is stamped with ``t_wall``, nanoseconds since the writer
    opened (schema v2), so replays can report measured time dilation.
    The stamp is written into the caller's dict — ``emit`` takes
    ownership of the record, which every in-tree producer satisfies by
    emitting a fresh dict per op. ``wall_clock=False`` is deterministic
    mode: no ``t_wall`` stamps and counter snapshots exclude
    measured-time (``*_ns``) statistics, so the same op stream produces
    a byte-identical trace file — the property the workload scenario
    suite's determinism tests pin down.

    ``buffer_records`` bounds the emission buffer (1 = write-through).
    """

    def __init__(self, path: str, mode: str = "binned",
                 meta: Optional[Dict] = None, wall_clock: bool = True,
                 buffer_records: int = BUFFER_RECORDS):
        self.path = str(path)
        self.wall_clock = wall_clock
        self._lock = threading.Lock()
        self._f = _open(self.path, write=True)
        self._buf: List[Dict] = []
        self._cap = max(int(buffer_records), 1)
        self.n_records = 0
        self._t0 = time.perf_counter_ns()
        self.emit(make_header(mode, meta))

    def _flush_locked(self) -> None:
        buf = self._buf
        if buf:
            self._f.write("\n".join(map(_encode, buf)) + "\n")
            self._buf = []

    def emit(self, rec: Dict) -> None:
        with self._lock:
            if self._f is None:
                raise ValueError(f"trace {self.path} is closed")
            if (self.wall_clock and rec.get("t") in _TIMED
                    and "t_wall" not in rec):
                rec["t_wall"] = time.perf_counter_ns() - self._t0
            self._buf.append(rec)
            self.n_records += 1
            if len(self._buf) >= self._cap:
                self._flush_locked()

    def flush(self) -> None:
        """Serialize and write everything buffered so far (no-op when
        closed); readers tailing the file see all emitted records."""
        with self._lock:
            if self._f is not None:
                self._flush_locked()
                self._f.flush()

    def snapshot(self, registry: CounterRegistry) -> None:
        """Write the registry's per-lane counter statistics as a ``snap``
        record (drains, so the snapshot reflects everything recorded so
        far; lane pids key the stats). In deterministic mode the
        wall-clock-measured ``*_ns`` statistics are dropped — they are
        the only nondeterministic content of a snapshot."""
        lanes = registry.drain_lanes()
        stats = {str(pid): {name: st.to_attrs()
                            for name, st in sorted(per.items())
                            if self.wall_clock or not name.endswith("_ns")}
                 for pid, per in sorted(lanes.items())}
        self.emit({"t": "snap", "stats": stats})

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._flush_locked()
                self._f.close()
                self._f = None

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_trace(path: str) -> Tuple[Dict, List[Dict]]:
    """Load and validate a trace: returns ``(header, records)``. Raises
    :class:`repro.trace.schema.TraceSchemaError` on a version or shape
    mismatch — the schema gate ``scripts/verify.sh`` exercises."""
    header: Optional[Dict] = None
    records: List[Dict] = []
    with _open(str(path), write=False) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if header is None:
                header = validate_header(rec)
            else:
                records.append(validate_record(rec))
    if header is None:
        raise TraceSchemaError(f"empty trace file (no header): {path}")
    return header, records
