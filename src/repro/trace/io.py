"""JSONL trace writer/reader (the persistence layer of ``repro.trace``).

The writer is the ``emit(dict)`` sink the instrumented layers speak
(:class:`repro.match.MatchEngine`, :class:`repro.match.Fabric`,
:class:`repro.comm.progress.ProgressEngine`): header first, ``.gz``
transparently compressed like :mod:`repro.core.timeline`.

Emission is buffered and, at schema v3 (the default), *compacted*:
consecutive same-kind ``post``/``arr`` records accumulate in a chunk
builder and are written as one columnar ``chk`` line per run (delta
encoding, run-length on constant columns — see
:mod:`repro.trace.schema`), so long runs cost ~a tenth of the per-op
bytes and one serialization per chunk instead of per record. Everything
else (and schema v2, which keeps the pre-compaction per-op encoding
byte-identical) goes through the PR 4 buffered path: records accumulate
in a per-writer list and are serialized in batches — one lock
acquisition, one ``"\\n".join`` of the batch, one file write. ``flush``
forces builder + buffer to disk; ``close`` flushes and is idempotent.

Reading is streaming: :class:`TraceReader` (also via :func:`iter_trace`)
validates the header eagerly, then yields records one line at a time,
expanding v3 chunks lazily — replaying a long trace never materializes
the full record list. :func:`read_trace` is the eager convenience over
it. Reader errors are typed: truncated or corrupt lines and unsupported
versions raise :class:`repro.trace.schema.TraceFormatError` carrying the
path and 1-based line number. ``strict=False`` turns a reader lenient:
corrupt payload lines (truncated JSON, non-object lines, invalid
records, undecodable chunks) are *skipped* instead of raised, tallied
by category in ``reader.skipped``, and summarized in one
:class:`TraceCorruptionWarning` when the stream ends — the header stays
strict either way (a trace without a valid header is not a trace).
"""
from __future__ import annotations

import gzip
import json
import threading
import time
import warnings
from typing import Dict, Iterator, List, Optional, Tuple

from ..core.counters import CounterRegistry
from .schema import (REC_ARRIVE, REC_CHUNK, REC_PE_CHUNK, REC_POST,
                     REC_PROGRESS, SCHEMA_VERSION, WRITABLE_VERSIONS,
                     TraceFormatError, TraceSchemaError, decode_chunk,
                     decode_pe_chunk, encode_flags, encode_ints,
                     encode_outcomes, make_header, validate_header,
                     validate_record)

# record types that carry live wall-clock timing in schema v2+
_TIMED = ("post", "arr", "pe")

# records buffered between batch serializations (a batch is ~100 bytes
# per record, so the default keeps ~25 KiB in flight)
BUFFER_RECORDS = 256

# rows per v3 chunk: caps the memory a builder holds and keeps chunk
# lines comfortably sized (~2-6 KiB) for line-oriented tooling
CHUNK_RECORDS = 512

# chunkable key sets per op kind: a record must match exactly (modulo
# the optional t_wall stamp) or it is written bare — external producers
# with extra/missing keys stay valid v3 without touching the chunk path
_POST_KEYS = frozenset(("t", "rank", "src", "tag", "comm", "seq", "hit"))
_ARR_KEYS = frozenset(("t", "rank", "src", "tag", "comm", "nb", "seq",
                       "match"))
_CHUNK_KEYS = {
    REC_POST: (_POST_KEYS, frozenset(_POST_KEYS | {"t_wall"})),
    REC_ARRIVE: (_ARR_KEYS, frozenset(_ARR_KEYS | {"t_wall"})),
}

# chunkable key sets for progress-lane ("pe") records, by event kind
_SUBMIT_KEYS = frozenset(("t", "ev", "ts", "wait"))
_PROC_KEYS = frozenset(("t", "ev", "ts", "dur"))
_PE_KEYS = {
    "submit": (_SUBMIT_KEYS, frozenset(_SUBMIT_KEYS | {"t_wall"})),
    "proc": (_PROC_KEYS, frozenset(_PROC_KEYS | {"t_wall"})),
}

# one shared encoder: json.dumps(..., separators=...) builds a fresh
# JSONEncoder per call, which is pure overhead at trace volume
_encode = json.JSONEncoder(separators=(",", ":")).encode


class TraceCorruptionWarning(UserWarning):
    """A lenient (``strict=False``) reader skipped corrupt lines; the
    message carries the per-category tally."""


def _open(path: str, write: bool, append: bool = False):
    if path.endswith(".gz"):
        # appending opens a new gzip member; readers decode the
        # concatenated members transparently
        return gzip.open(path, ("at" if append else "wt") if write
                         else "rt")
    return open(path, ("a" if append else "w") if write else "r")


class TraceWriter:
    """Append-only trace sink with a versioned header.

    Usable as a context manager; ``close`` is idempotent. ``n_records``
    counts logical records emitted including the header (chunked and
    buffered records included — everything is on disk after
    ``flush``/``close``).

    With ``wall_clock=True`` (the default) every engine-op / progress
    record is stamped with ``t_wall``, nanoseconds since the writer
    opened (schema v2+), so replays can report measured time dilation.
    The stamp is written into the caller's dict — ``emit`` takes
    ownership of the record, which every in-tree producer satisfies by
    emitting a fresh dict per op. ``wall_clock=False`` is deterministic
    mode: no ``t_wall`` stamps and counter snapshots exclude
    measured-time (``*_ns``) statistics, so the same op stream produces
    a byte-identical trace file — the property the workload scenario
    suite's determinism tests pin down.

    ``schema`` picks the encoding: 3 (the default) compacts post/arrive
    runs into columnar chunks (and progress-lane runs into ``pec``
    chunks); 2 writes the per-op records of the pre-compaction format
    byte-identically (the committed golden traces stay frozen at v2).
    ``buffer_records`` bounds the emission buffer (1 = write-through;
    chunks count as one buffered record).

    ``append=True`` re-opens an **existing** trace and continues it:
    the header is validated, the stream is scanned to re-seed the
    per-rank derived-seq counters from the tail (so later chunks keep
    reconstructing correctly), ``n_records`` resumes from the existing
    count, and new ``t_wall`` stamps continue monotonically after the
    largest recorded one. ``mode``/``meta`` are ignored (the existing
    header stands) and ``wall_clock`` is inferred from the recorded
    stream — a deterministic trace stays byte-deterministic across
    sessions, a wall-clock one keeps stamping. ``schema`` defaults to
    the file's version; an
    explicit *lower* writable version is allowed (bare v2 records are
    legal inside a v3 file), a higher one is rejected.
    """

    def __init__(self, path: str, mode: str = "binned",
                 meta: Optional[Dict] = None, wall_clock: bool = True,
                 buffer_records: int = BUFFER_RECORDS,
                 schema: Optional[int] = None, append: bool = False):
        self.path = str(path)
        self.wall_clock = wall_clock
        self._lock = threading.Lock()
        self._buf: List[Dict] = []
        self._cap = max(int(buffer_records), 1)
        self._chunk: List[Dict] = []     # pending chunkable records
        self._cflags: List[int] = []     # op: 1 = post row, 0 = arr row
        #                                  pe: 1 = submit,   0 = proc
        self._ctimed = False             # pending chunk carries t_wall
        self._ckind = "op"               # pending chunk kind: op | pe
        self._seqs: Dict[int, int] = {}  # per-rank next expected seq
        if append:
            try:
                (hdr, seqs, n_records, max_tw,
                 saw_tw) = self._scan_existing()
            except FileNotFoundError:
                raise TraceFormatError(
                    "cannot append: no existing trace at this path "
                    "(open without append=True to start one)",
                    path=self.path) from None
            # adopt the file's clock discipline: a deterministic trace
            # (no t_wall anywhere) must stay byte-deterministic across
            # append sessions; an empty trace keeps the caller's choice
            if n_records > 1:
                self.wall_clock = saw_tw
            file_schema = hdr.get("schema")
            if file_schema not in WRITABLE_VERSIONS:
                raise TraceSchemaError(
                    f"cannot append to a schema v{file_schema} trace "
                    f"(appendable: {WRITABLE_VERSIONS})")
            self.schema = (file_schema if schema is None
                           else int(schema))
            if self.schema not in WRITABLE_VERSIONS:
                raise TraceSchemaError(
                    f"cannot write schema v{self.schema} (writable: "
                    f"{WRITABLE_VERSIONS})")
            if self.schema > file_schema:
                raise TraceSchemaError(
                    f"cannot append v{self.schema} records to a "
                    f"v{file_schema} trace (bare lower-version records "
                    f"are legal in a newer file, not the reverse)")
            self._seqs = seqs
            self.n_records = n_records
            self._f = _open(self.path, write=True, append=True)
            # continue the live clock where the recorded one stopped
            self._t0 = time.perf_counter_ns() - max_tw
            return
        self.schema = SCHEMA_VERSION if schema is None else int(schema)
        if self.schema not in WRITABLE_VERSIONS:
            raise TraceSchemaError(
                f"cannot write schema v{self.schema} (writable: "
                f"{WRITABLE_VERSIONS})")
        self._f = _open(self.path, write=True)
        self.n_records = 0
        self._t0 = time.perf_counter_ns()
        self.emit(make_header(mode, meta, schema=self.schema))

    def _scan_existing(self):
        """Stream-validate the trace being appended to: returns
        ``(header, per-rank next seqs, logical record count including
        the header, max t_wall seen)``. Chunks are expanded so the
        count matches what ``emit`` would have accumulated."""
        n = 1                            # the header line
        max_tw = 0
        saw_tw = False
        with TraceReader(self.path, expand=True) as r:
            hdr = r.header
            for rec in r:
                n += 1
                tw = rec.get("t_wall")
                if tw is not None:
                    saw_tw = True
                    if type(tw) is int and tw > max_tw:
                        max_tw = tw
            seqs = dict(r._seqs)
        return hdr, seqs, n, max_tw, saw_tw

    def _flush_chunk_locked(self) -> None:
        recs = self._chunk
        if not recs:
            return
        flags = self._cflags
        self._chunk = []
        self._cflags = []
        if len(recs) == 1:
            # a bare record is smaller than a 1-row chunk
            self._buf.append(recs[0])
            return
        if self._ckind == "pe":
            self._flush_pe_chunk(recs, flags)
            return
        out: Dict = {"t": REC_CHUNK, "n": len(recs),
                     "p": encode_flags(flags)}
        for key, col in (("r", "rank"), ("s", "src"), ("g", "tag"),
                         ("c", "comm")):
            values = [r[col] for r in recs]
            if any(type(v) is not int for v in values):
                # non-int payload (an external producer): the delta
                # codec only round-trips ints — write the run bare
                self._buf.extend(recs)
                return
            enc = encode_ints(values)
            if key != "c" or enc != 0:   # comm omitted when all-zero
                out[key] = enc
        arrs = [r for r, p in zip(recs, flags) if not p]
        posts = [r for r, p in zip(recs, flags) if p]
        nbs = [r["nb"] for r in arrs]
        hits = [r["hit"] for r in posts]
        matches = [r["match"] for r in arrs]
        tws = [r["t_wall"] for r in recs] if self._ctimed else []
        if (any(type(v) is not int for v in nbs + tws)
                or any(v is not None and type(v) is not int
                       for v in hits + matches)):
            self._buf.extend(recs)
            return
        if nbs:
            benc = encode_ints(nbs)
            if benc != 0:                # nbytes omitted when all-zero
                out["b"] = benc
        henc = encode_outcomes(hits) if hits else None
        if henc is not None:
            out["h"] = henc
        menc = encode_outcomes(matches) if matches else None
        if menc is not None:
            out["m"] = menc
        if tws:
            out["w"] = encode_ints(tws)
        self._buf.append(out)

    def _flush_pe_chunk(self, recs: List[Dict],
                        flags: List[int]) -> None:
        """Columnar-encode a run of chunkable ``pe`` records (``flags``:
        1 = submit row, 0 = proc row) as one ``pec`` line."""
        tss = [r["ts"] for r in recs]
        waits = [r["wait"] for r, e in zip(recs, flags) if e]
        durs = [r["dur"] for r, e in zip(recs, flags) if not e]
        tws = [r["t_wall"] for r in recs] if self._ctimed else []
        if any(type(v) is not int for v in tss + waits + durs + tws):
            # non-int payload: the delta codec only round-trips ints
            self._buf.extend(recs)
            return
        out: Dict = {"t": REC_PE_CHUNK, "n": len(recs),
                     "e": encode_flags(flags), "s": encode_ints(tss)}
        if waits:
            uenc = encode_ints(waits)
            if uenc != 0:                # waits omitted when all-zero
                out["u"] = uenc
        if durs:
            denc = encode_ints(durs)
            if denc != 0:
                out["d"] = denc
        if tws:
            out["w"] = encode_ints(tws)
        self._buf.append(out)

    def _flush_locked(self) -> None:
        self._flush_chunk_locked()
        buf = self._buf
        if buf:
            self._f.write("\n".join(map(_encode, buf)) + "\n")
            self._buf = []

    def emit(self, rec: Dict) -> None:
        with self._lock:
            if self._f is None:
                raise ValueError(f"trace {self.path} is closed")
            kind = rec.get("t")
            if (self.wall_clock and kind in _TIMED
                    and "t_wall" not in rec):
                rec["t_wall"] = time.perf_counter_ns() - self._t0
            self.n_records += 1
            is_post = kind == REC_POST
            if self.schema >= 3 and (is_post or kind == REC_ARRIVE):
                keys = _CHUNK_KEYS[kind]
                rk = rec.keys()
                timed = rk == keys[1]
                seqs = self._seqs
                rank = rec.get("rank")
                seq = rec.get("seq")
                if ((timed or rk == keys[0]) and type(rank) is int
                        and type(seq) is int
                        and seq == seqs.get(rank, 0)):
                    # chunkable: seq is derivable (dense per-rank
                    # numbering), so it is dropped from the encoding
                    if ((timed != self._ctimed
                            or self._ckind != "op") and self._chunk):
                        self._flush_chunk_locked()
                    self._ctimed = timed
                    self._ckind = "op"
                    seqs[rank] = seq + 1
                    self._chunk.append(rec)
                    self._cflags.append(1 if is_post else 0)
                    if len(self._chunk) >= CHUNK_RECORDS:
                        self._flush_chunk_locked()
                        if len(self._buf) >= self._cap:
                            self._flush_locked()
                    return
                # bare op record: re-seed the rank's seq counter so
                # later chunk rows keep reconstructing correctly
                if type(rank) is int and type(seq) is int:
                    seqs[rank] = seq + 1
            elif self.schema >= 3 and kind == REC_PROGRESS:
                keys = _PE_KEYS.get(rec.get("ev"))
                if keys is not None:
                    rk = rec.keys()
                    timed = rk == keys[1]
                    if timed or rk == keys[0]:
                        if ((timed != self._ctimed
                                or self._ckind != "pe")
                                and self._chunk):
                            self._flush_chunk_locked()
                        self._ctimed = timed
                        self._ckind = "pe"
                        self._chunk.append(rec)
                        self._cflags.append(
                            1 if rec["ev"] == "submit" else 0)
                        if len(self._chunk) >= CHUNK_RECORDS:
                            self._flush_chunk_locked()
                            if len(self._buf) >= self._cap:
                                self._flush_locked()
                        return
            self._flush_chunk_locked()
            self._buf.append(rec)
            if len(self._buf) >= self._cap:
                self._flush_locked()

    def flush(self) -> None:
        """Serialize and write everything buffered so far, the pending
        chunk included (no-op when closed); readers tailing the file see
        all emitted records."""
        with self._lock:
            if self._f is not None:
                self._flush_locked()
                self._f.flush()

    def snapshot(self, registry: Optional[CounterRegistry],
                 lanes=None) -> None:
        """Write the registry's per-lane counter statistics as a ``snap``
        record (drains, so the snapshot reflects everything recorded so
        far; lane pids key the stats). In deterministic mode the
        wall-clock-measured ``*_ns`` statistics are dropped — they are
        the only nondeterministic content of a snapshot. When a live
        telemetry bridge was draining the registry concurrently, pass
        its cumulative ``lanes`` instead (registry may be None then):
        the registry's own remainder would be a partial view."""
        if lanes is None:
            lanes = registry.drain_lanes()
        stats = {str(pid): {name: st.to_attrs()
                            for name, st in sorted(per.items())
                            if self.wall_clock or not name.endswith("_ns")}
                 for pid, per in sorted(lanes.items())}
        self.emit({"t": "snap", "stats": stats})

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._flush_locked()
                self._f.close()
                self._f = None

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class TraceReader:
    """Streaming trace reader: the header is read and validated eagerly
    (available as ``.header``); iterating yields validated records one
    at a time with v3 chunks expanded lazily, so consumers never hold
    the full record list. ``expand=False`` yields raw records (chunks
    intact) for columnar consumers like the batched replayer.

    Usable as a context manager; iteration closes the file when the
    stream ends. Malformed input raises
    :class:`~repro.trace.schema.TraceFormatError` with the offending
    line number — unless ``strict=False``, which skips corrupt payload
    lines (counting them by category in ``skipped``: ``"json"`` for
    unparseable/non-object lines, ``"record"`` for invalid records,
    ``"chunk"`` for undecodable chunk columns) and warns once with the
    tally when the stream ends. The header is validated strictly
    regardless."""

    def __init__(self, path: str, expand: bool = True,
                 strict: bool = True):
        self.path = str(path)
        self.expand = expand
        self.strict = strict
        self.skipped: Dict[str, int] = {}
        self._lineno = 0
        self._seqs: Dict[int, int] = {}  # per-rank next derived seq
        self._f = _open(self.path, write=False)
        try:
            self.header: Dict = self._read_header()
        except BaseException:
            self.close()
            raise

    def _fail(self, message: str) -> TraceFormatError:
        return TraceFormatError(message, path=self.path, line=self._lineno)

    def _parse(self, line: str) -> Dict:
        try:
            rec = json.loads(line)
        except ValueError as e:
            raise self._fail(f"corrupt trace line: {e}") from None
        if not isinstance(rec, dict):
            raise self._fail("trace line is not a JSON object")
        return rec

    def _read_header(self) -> Dict:
        for line in self._f:
            self._lineno += 1
            line = line.strip()
            if not line:
                continue
            rec = self._parse(line)
            try:
                return validate_header(rec)
            except TraceFormatError:
                raise
            except TraceSchemaError as e:
                raise self._fail(str(e)) from None
        raise self._fail(f"empty trace file (no header): {self.path}")

    def _skip(self, category: str) -> None:
        self.skipped[category] = self.skipped.get(category, 0) + 1

    def __iter__(self) -> Iterator[Dict]:
        f = self._f
        if f is None:
            raise ValueError(f"trace reader for {self.path} is closed")
        expand = self.expand
        strict = self.strict
        v3 = self.header.get("schema", 0) >= 3
        try:
            for line in f:
                self._lineno += 1
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = self._parse(line)
                except TraceFormatError:
                    if strict:
                        raise
                    self._skip("json")
                    continue
                try:
                    validate_record(rec)
                except TraceFormatError:
                    if strict:
                        raise
                    self._skip("record")
                    continue
                except TraceSchemaError as e:
                    if strict:
                        raise self._fail(str(e)) from None
                    self._skip("record")
                    continue
                if v3:
                    # chunk expansion + derived-seq bookkeeping only
                    # exist at v3; pre-chunk files skip both
                    kind = rec.get("t")
                    if (not strict and not expand
                            and (kind == REC_CHUNK
                                 or kind == REC_PE_CHUNK)):
                        # raw lenient stream (the batched replayer):
                        # trial-decode against scratch state so a
                        # corrupt chunk is skipped here rather than
                        # exploding inside a columnar consumer
                        try:
                            if kind == REC_CHUNK:
                                for _ in decode_chunk(rec,
                                                      dict(self._seqs)):
                                    pass
                            else:
                                for _ in decode_pe_chunk(rec):
                                    pass
                        except (TraceFormatError, TraceSchemaError,
                                ValueError, TypeError, IndexError,
                                KeyError):
                            self._skip("chunk")
                            continue
                        yield rec
                        continue
                    if expand and (kind == REC_CHUNK
                                   or kind == REC_PE_CHUNK):
                        if strict:
                            try:
                                if kind == REC_CHUNK:
                                    yield from decode_chunk(
                                        rec, self._seqs)
                                else:
                                    yield from decode_pe_chunk(rec)
                            except TraceFormatError:
                                raise
                            except TraceSchemaError as e:
                                raise self._fail(str(e)) from None
                            continue
                        # lenient: decode eagerly against a scratch
                        # seq map so a wrong-arity chunk is skipped
                        # whole, never half-expanded
                        seqs = dict(self._seqs)
                        try:
                            if kind == REC_CHUNK:
                                rows = list(decode_chunk(rec, seqs))
                            else:
                                rows = list(decode_pe_chunk(rec))
                        except (TraceFormatError, TraceSchemaError,
                                ValueError, TypeError, IndexError,
                                KeyError):
                            self._skip("chunk")
                            continue
                        self._seqs = seqs
                        yield from rows
                        continue
                    if kind == REC_POST or kind == REC_ARRIVE:
                        # bare op: re-seed the rank's derived-seq
                        # counter (mirrors the writer's fallback)
                        rank, seq = rec.get("rank"), rec.get("seq")
                        if type(rank) is int and type(seq) is int:
                            self._seqs[rank] = seq + 1
                yield rec
            if self.skipped:
                warnings.warn(TraceCorruptionWarning(
                    f"{self.path}: skipped "
                    + ", ".join(f"{n} {cat} line(s)" for cat, n
                                in sorted(self.skipped.items()))),
                    stacklevel=2)
        finally:
            self.close()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "TraceReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def iter_trace(path: str, expand: bool = True,
               strict: bool = True) -> TraceReader:
    """Streaming open: ``with iter_trace(p) as r: r.header; for rec in
    r: ...`` — decodes chunks lazily, one record in memory at a time.
    ``strict=False`` skips corrupt payload lines instead of raising
    (tallied in ``reader.skipped``)."""
    return TraceReader(path, expand=expand, strict=strict)


def read_trace(path: str) -> Tuple[Dict, List[Dict]]:
    """Eagerly load and validate a trace: returns ``(header, records)``
    with chunks expanded. Raises :class:`repro.trace.schema
    .TraceFormatError` (a :class:`~repro.trace.schema.TraceSchemaError`)
    on a version or shape mismatch — the schema gate
    ``scripts/verify.sh`` exercises."""
    with TraceReader(path) as r:
        return r.header, list(r)


def convert_trace(src: str, dst: str, schema: Optional[int] = None,
                  strict: bool = True,
                  skipped: Optional[Dict[str, int]] = None
                  ) -> Tuple[int, int]:
    """Re-encode a trace at another schema version (v2 <-> v3) without
    touching its content: records stream through unchanged — ``t_wall``
    stamps, phase markers, snapshots and meta are preserved — only the
    post/arrive encoding changes. Returns ``(n_records, n_ops)``.
    Converting v2 -> v3 -> v2 is byte-identical; replay statistics are
    equal in every direction (``scripts/trace_convert.py`` is the
    CLI). ``strict=False`` salvages a damaged source: corrupt lines
    are dropped from the converted output and tallied into the
    caller's ``skipped`` dict (the CLI's ``--lenient``)."""
    n_ops = 0
    with TraceReader(src, strict=strict) as r:
        hdr = r.header
        with TraceWriter(dst, mode=hdr.get("mode", "binned"),
                         meta=hdr.get("meta") or None, wall_clock=False,
                         schema=schema) as w:
            for rec in r:
                if rec["t"] in (REC_POST, REC_ARRIVE):
                    n_ops += 1
                w.emit(rec)
            if skipped is not None:
                skipped.update(r.skipped)
            return w.n_records, n_ops
