"""JSONL trace writer/reader (the persistence layer of ``repro.trace``).

The writer is the ``emit(dict)`` sink the instrumented layers speak
(:class:`repro.match.MatchEngine`, :class:`repro.match.Fabric`,
:class:`repro.comm.progress.ProgressEngine`): one compact JSON object per
line, header first, ``.gz`` transparently compressed like
:mod:`repro.core.timeline`. ``emit`` is serialized by a lock because the
progress engine writes from two threads.
"""
from __future__ import annotations

import gzip
import json
import threading
from typing import Dict, List, Optional, Tuple

from ..core.counters import CounterRegistry
from .schema import (TraceSchemaError, make_header, validate_header,
                     validate_record)


def _open(path: str, write: bool):
    if path.endswith(".gz"):
        return gzip.open(path, "wt" if write else "rt")
    return open(path, "w" if write else "r")


class TraceWriter:
    """Append-only trace sink with a versioned header.

    Usable as a context manager; ``close`` is idempotent. ``n_records``
    counts everything written including the header.
    """

    def __init__(self, path: str, mode: str = "binned",
                 meta: Optional[Dict] = None):
        self.path = str(path)
        self._lock = threading.Lock()
        self._f = _open(self.path, write=True)
        self.n_records = 0
        self._emit_unlocked(make_header(mode, meta))

    def _emit_unlocked(self, rec: Dict) -> None:
        self._f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self.n_records += 1

    def emit(self, rec: Dict) -> None:
        with self._lock:
            if self._f is None:
                raise ValueError(f"trace {self.path} is closed")
            self._emit_unlocked(rec)

    def snapshot(self, registry: CounterRegistry) -> None:
        """Write the registry's per-lane counter statistics as a ``snap``
        record (drains, so the snapshot reflects everything recorded so
        far; lane pids key the stats)."""
        lanes = registry.drain_lanes()
        stats = {str(pid): {name: st.to_attrs()
                            for name, st in sorted(per.items())}
                 for pid, per in sorted(lanes.items())}
        self.emit({"t": "snap", "stats": stats})

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_trace(path: str) -> Tuple[Dict, List[Dict]]:
    """Load and validate a trace: returns ``(header, records)``. Raises
    :class:`repro.trace.schema.TraceSchemaError` on a version or shape
    mismatch — the schema gate ``scripts/verify.sh`` exercises."""
    header: Optional[Dict] = None
    records: List[Dict] = []
    with _open(str(path), write=False) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if header is None:
                header = validate_header(rec)
            else:
                records.append(validate_record(rec))
    if header is None:
        raise TraceSchemaError(f"empty trace file (no header): {path}")
    return header, records
