"""Trace differ: the regression-detection primitive over replays.

Given two replays of the *same* recorded trace (or of two runs with the
same phase structure), align them phase-by-phase and rank-by-rank —
phases carry the (op, label, tag) identity of the collective that
produced them — and report deltas in the method-2 quantities:

  * PRQ traversal depth (queue entries examined per match),
  * UMQ length (unexpected messages pending, leaks included),
  * match latency (measured PRQ+UMQ search nanoseconds).

``TraceDiff.flags()`` turns aggregate deltas into the same
:class:`repro.core.analyses.Finding` kinds the live detectors emit
(``long_traversal`` / ``umq_flood``), so "replay the trace on engine B
and diff against engine A" answers the what-if question directly: a
defective candidate engine is flagged, a healthy one diffs clean.

``TraceDiff.to_report()`` renders the diff as the unified
:class:`repro.core.comparison.ProfileReport` — the same type GraphFrame
comparisons produce — so trace diffs and method-1 comparisons flow
through one report pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from ..core.analyses import NS_PER_QUEUE_ENTRY, Finding
from ..core.comparison import ProfileReport, ReportRow
from ..core.counters import CounterStat
from .replay import PhaseStats, ReplayResult

DEPTH = "match.prq.traversal_depth"
UMQ_LEN = "match.umq.length"
SEARCH = ("match.prq.search_ns", "match.umq.search_ns")


def _mean_count(stats: Dict[str, CounterStat], name: str
                ) -> Tuple[float, int, float]:
    """(mean, count, vmax) of one histogram, zeros when absent."""
    st = stats.get(name)
    if st is None or st.count == 0:
        return 0.0, 0, 0.0
    vmax = st.vmax if st.kind == "histogram" else 0.0
    return st.mean, st.count, vmax


def _search_ns(stats: Dict[str, CounterStat]) -> float:
    return sum(stats[n].total for n in SEARCH if n in stats)


@dataclasses.dataclass
class PhaseDelta:
    """One (phase, rank) cell of the diff. ``a`` is the baseline replay,
    ``b`` the candidate."""

    index: int
    label: str
    op: str
    rank: int
    depth_mean: Tuple[float, float]
    depth_count: Tuple[int, int]
    umq_len_mean: Tuple[float, float]
    umq_len_max: Tuple[float, float]
    match_ns: Tuple[float, float]

    @property
    def latency_delta_s(self) -> float:
        return (self.match_ns[1] - self.match_ns[0]) / 1e9

    def __str__(self) -> str:
        return (f"phase {self.index} '{self.label}' rank {self.rank}: "
                f"depth {self.depth_mean[0]:.1f}->{self.depth_mean[1]:.1f} "
                f"umq_max {self.umq_len_max[0]:.0f}->"
                f"{self.umq_len_max[1]:.0f} "
                f"latency {self.latency_delta_s * 1e3:+.3f} ms")


def _phase_deltas(pa: PhaseStats, pb: PhaseStats) -> List[PhaseDelta]:
    out: List[PhaseDelta] = []
    for rank in sorted(set(pa.stats) | set(pb.stats)):
        sa = pa.stats.get(rank, {})
        sb = pb.stats.get(rank, {})
        da_mean, da_count, _ = _mean_count(sa, DEPTH)
        db_mean, db_count, _ = _mean_count(sb, DEPTH)
        ua_mean, _, ua_max = _mean_count(sa, UMQ_LEN)
        ub_mean, _, ub_max = _mean_count(sb, UMQ_LEN)
        out.append(PhaseDelta(
            index=pa.index, label=pa.label, op=pa.op, rank=rank,
            depth_mean=(da_mean, db_mean),
            depth_count=(da_count, db_count),
            umq_len_mean=(ua_mean, ub_mean),
            umq_len_max=(ua_max, ub_max),
            match_ns=(_search_ns(sa), _search_ns(sb)),
        ))
    return out


@dataclasses.dataclass
class TraceDiff:
    a_mode: str
    b_mode: str
    deltas: List[PhaseDelta]

    def per_rank(self) -> Dict[int, Dict[str, float]]:
        """Aggregate deltas across phases, per rank (depth totals are
        sample-weighted so one deep phase is not averaged away)."""
        agg: Dict[int, Dict[str, float]] = {}
        for d in self.deltas:
            r = agg.setdefault(d.rank, {
                "depth_total_a": 0.0, "depth_total_b": 0.0,
                "depth_count_a": 0.0, "depth_count_b": 0.0,
                "umq_max_a": 0.0, "umq_max_b": 0.0,
                "match_ns_a": 0.0, "match_ns_b": 0.0,
            })
            r["depth_total_a"] += d.depth_mean[0] * d.depth_count[0]
            r["depth_total_b"] += d.depth_mean[1] * d.depth_count[1]
            r["depth_count_a"] += d.depth_count[0]
            r["depth_count_b"] += d.depth_count[1]
            r["umq_max_a"] = max(r["umq_max_a"], d.umq_len_max[0])
            r["umq_max_b"] = max(r["umq_max_b"], d.umq_len_max[1])
            r["match_ns_a"] += d.match_ns[0]
            r["match_ns_b"] += d.match_ns[1]
        return agg

    def flags(self, depth_factor: float = 4.0, depth_mean: float = 8.0,
              min_depth_samples: int = 32, umq_factor: float = 4.0,
              umq_len: float = 64.0) -> List[Finding]:
        """Findings for ranks where the candidate replay regressed past
        the thresholds (same kinds and thresholds style as the live
        ``long_traversal`` / ``umq_flood`` detectors; severity is the
        deterministic excess-traversal cost, not wall time, so flags are
        reproducible run to run)."""
        out: List[Finding] = []
        for rank, agg in sorted(self.per_rank().items()):
            mean_a = agg["depth_total_a"] / max(agg["depth_count_a"], 1.0)
            mean_b = agg["depth_total_b"] / max(agg["depth_count_b"], 1.0)
            if (agg["depth_count_b"] >= min_depth_samples
                    and mean_b >= depth_mean
                    and mean_b >= depth_factor * max(mean_a, 1.0)):
                excess = agg["depth_total_b"] - agg["depth_total_a"]
                out.append(Finding(
                    kind="long_traversal",
                    message=(
                        f"replayed {self.b_mode!r} traverses the PRQ "
                        f"{mean_b:.1f} deep vs {mean_a:.1f} on "
                        f"{self.a_mode!r} (rank {rank}, "
                        f"{int(agg['depth_count_b'])} matches, "
                        f"{(agg['match_ns_b'] - agg['match_ns_a']) / 1e6:+.3f}"
                        f" ms measured)"),
                    severity=excess * NS_PER_QUEUE_ENTRY / 1e9,
                ))
            if (agg["umq_max_b"] >= umq_len
                    and agg["umq_max_b"]
                    >= umq_factor * max(agg["umq_max_a"], 1.0)):
                out.append(Finding(
                    kind="umq_flood",
                    message=(
                        f"replayed {self.b_mode!r} grows the UMQ to "
                        f"{agg['umq_max_b']:.0f} vs {agg['umq_max_a']:.0f} "
                        f"on {self.a_mode!r} (rank {rank})"),
                    severity=(agg["umq_max_b"] - agg["umq_max_a"])
                    * NS_PER_QUEUE_ENTRY / 1e9,
                ))
        out.sort(key=lambda f: -f.severity)
        return out

    def to_report(self) -> ProfileReport:
        """The unified report: one row per (phase, rank) cell carrying
        measured match latency (seconds), findings from :meth:`flags`."""
        rows = [ReportRow(
            path=f"phase{d.index}:{d.label}/rank{d.rank}",
            baseline=d.match_ns[0] / 1e9,
            candidate=d.match_ns[1] / 1e9,
        ) for d in self.deltas]
        return ProfileReport(kind="trace", baseline_name=self.a_mode,
                             candidate_name=self.b_mode, rows=rows,
                             findings=self.flags())

    def report(self, limit: int = 12) -> str:
        worst = sorted(
            (d for d in self.deltas
             if d.depth_count[0] or d.depth_count[1]),
            key=lambda d: -(abs(d.latency_delta_s)
                            + abs(d.depth_mean[1] - d.depth_mean[0])))
        lines = [f"trace diff: {self.a_mode!r} -> {self.b_mode!r}, "
                 f"{len(self.deltas)} (phase, rank) cells"]
        lines += [str(d) for d in worst[:limit]]
        for f in self.flags():
            lines.append(str(f))
        return "\n".join(lines)


def diff(a: ReplayResult, b: ReplayResult,
         align: str = "index") -> TraceDiff:
    """Diff two replays phase-by-phase.

    ``align="index"`` (the default) zips phases positionally: replays
    of the same trace align exactly, and alignment stops at the first
    structural ``(op, label)`` mismatch — right for same-trace what-if
    comparisons.

    ``align="label"`` aligns *different runs* whose phase indices
    diverge (extra warmup rounds, a skipped collective, interleaved
    extra phases): the k-th occurrence of each ``(op, label)`` identity
    in ``a`` is paired with the k-th occurrence in ``b``, in ``a``'s
    order; unmatched phases on either side are left out of the diff
    rather than poisoning the cells after a divergence point. This is
    the cross-trace mode ``benchmarks/replay_sweep.py --align=label``
    surfaces."""
    deltas: List[PhaseDelta] = []
    if align == "index":
        for pa, pb in zip(a.phases, b.phases):
            if (pa.op, pa.label) != (pb.op, pb.label):
                break
            deltas.extend(_phase_deltas(pa, pb))
    elif align == "label":
        by_key: Dict[Tuple[str, str], List[PhaseStats]] = {}
        for pb in b.phases:
            by_key.setdefault((pb.op, pb.label), []).append(pb)
        taken: Dict[Tuple[str, str], int] = {}
        for pa in a.phases:
            key = (pa.op, pa.label)
            i = taken.get(key, 0)
            cands = by_key.get(key)
            if cands is None or i >= len(cands):
                continue
            taken[key] = i + 1
            deltas.extend(_phase_deltas(pa, cands[i]))
    else:
        raise ValueError(
            f"align must be 'index' or 'label', got {align!r}")
    return TraceDiff(a_mode=a.mode, b_mode=b.mode, deltas=deltas)
