"""Versioned record schema for ``repro.trace`` JSONL traces.

A trace is one JSON object per line. The first line is the header; every
following line is a record tagged by its ``"t"`` field:

  ``hdr``    header: ``format``, ``schema`` (version), the engine ``mode``
             the run was recorded under, free-form ``meta``.
  ``post``   MPI_Irecv analog on one rank: envelope (``src``/``tag``/
             ``comm``), the per-engine sequence number ``seq``, and the
             match outcome ``hit`` (seq of the unexpected message the
             receive pulled from the UMQ, or null).
  ``arr``    network delivery on one rank: envelope plus payload size
             ``nb``, ``seq``, and outcome ``match`` (seq of the posted
             receive the message matched, or null -> parked on the UMQ).
  ``phase``  phase marker: ``op`` (collective kind or ``"phase"`` for
             explicit markers), human ``label``, optional attrs (``n``,
             ``nb``, ``tag``). The replayer snapshots counters at every
             marker — this is the alignment unit the differ works in.
  ``pe``     progress-engine lane event: ``ev`` = ``submit`` (``ts``,
             lock ``wait``) or ``proc`` (``ts``, processing ``dur``),
             nanosecond timestamps.
  ``snap``   counter snapshot: per-pid ``stats`` in the
             :meth:`repro.core.counters.CounterStat.to_attrs` encoding.
  ``chk``    **schema v3** chunk: a run of consecutive ``post``/``arr``
             records (kinds freely mixed), columnar-encoded (see
             below). One chunk line replaces up to
             :data:`~repro.trace.io.CHUNK_RECORDS` per-op lines.
  ``pec``    **schema v3** progress-lane chunk: a run of consecutive
             ``pe`` records (``submit``/``proc`` freely mixed),
             columnar-encoded with the same codecs as ``chk``.

Chunk layout (v3). A chunk carries ``n`` (row count) plus one encoded
column per logical field, single-letter keys::

  {"t":"chk","n":N,"p":F,"r":C,"s":C,"g":C,"c":C?,"b":C?,"h":O?,
   "m":O?,"w":C?}

``p`` (is-post flags, 1 = ``post`` row, 0 = ``arr`` row) is a bare int
when uniform, else a run-length pair list ``[v0,n0,v1,n1,...]`` — an
exchange phase's post/arrive/late-post stages become three pairs.
Integer columns ``C`` — ``r`` rank, ``s`` src, ``g`` tag, ``c`` comm,
``b`` nbytes, ``w`` t_wall — are either a bare int (run-length-constant
column: the value shared by every row) or a **delta list**
``[v0, v1-v0, v2-v1, ...]`` (phase-local envelopes and monotone
``t_wall`` streams make the deltas small, which is where the byte
shrink comes from). Outcome columns ``O`` (``h`` = post ``hit``, ``m``
= arr ``match``) are nullable and never delta-encoded: the raw value
list, or omitted when every value is null (the common miss/park case).
``c`` defaults to 0 when absent. ``b``/``h`` apply only to their kind's
rows and have that sub-population's length (``b``/``m`` over arr rows,
``h`` over post rows); ``w`` is present only when the compacted records
carried timing.

Progress-lane chunk layout (v3)::

  {"t":"pec","n":N,"e":F,"s":C,"u":C?,"d":C?,"w":C?}

``e`` (is-submit flags, 1 = ``submit`` row, 0 = ``proc`` row) uses the
same run-length form as ``p``. ``s`` is the ``ts`` column (delta-encoded
— submit timestamps are monotone, so deltas are small). ``u`` (submit
``wait``) spans the submit rows only and ``d`` (processing ``dur``) the
proc rows only; each is omitted when its sub-population is empty or
all-zero (waits usually are). ``w`` is ``t_wall``, present only when
the compacted records carried timing. ``pe`` records have no ``seq``,
so expansion needs no cross-chunk state — decoding reproduces the
per-op records exactly, key order included.

Per-op ``seq`` numbers are **derived, not stored**: every engine
numbers its ops densely from 0 in emission order, so the decoder
reconstructs ``seq`` with one per-rank counter threaded across the
whole stream (bare ``post``/``arr`` records re-seed their rank's
counter from their explicit ``seq``). The writer verifies the invariant
per record and falls back to bare records whenever a producer's seqs
are not dense, so expanding a chunk reproduces the per-op records
exactly — key order included — and converting a v2 trace to v3 and
back is byte-identical.

Version history:

  * **v1** — the per-op record types above, no per-op timing.
  * **v2** — ``post``/``arr``/``pe`` records may carry ``t_wall``:
    live wall-clock nanoseconds since the writer opened, stamped by
    :class:`repro.trace.io.TraceWriter` (``wall_clock=True``, the
    default). Optional — a writer in deterministic mode omits it, and
    v1 traces never have it — so readers treat it as advisory timing
    (the replayer surfaces it as measured per-phase wall time /
    dilation).
  * **v3** — compact chunked encoding: the post/arrive streams are
    delta-encoded into columnar ``chk`` records and the progress-lane
    stream into ``pec`` records. Bare ``post``/``arr``/``pe`` records
    remain legal in a v3 file (the writer falls back to them for
    single-record runs and nonconforming producer dicts); readers
    expand chunks transparently, so every consumer of v1/v2 records
    keeps working unchanged.

Schema changes MUST bump :data:`SCHEMA_VERSION`; readers accept every
version in :data:`SUPPORTED_VERSIONS` and reject anything newer
(``scripts/verify.sh`` gates on this round-tripping). Writers speak
:data:`WRITABLE_VERSIONS` — ``scripts/trace_convert.py`` re-encodes a
trace in either direction.
"""
from __future__ import annotations

from itertools import accumulate
from typing import Dict, List, Optional

SCHEMA_VERSION = 3
SUPPORTED_VERSIONS = (1, 2, 3)
# versions a TraceWriter can emit: 2 = per-op records (the pre-compaction
# encoding, byte-identical to the PR 4 writer), 3 = chunked
WRITABLE_VERSIONS = (2, 3)
TRACE_FORMAT = "repro.trace"

REC_HEADER = "hdr"
REC_POST = "post"
REC_ARRIVE = "arr"
REC_PHASE = "phase"
REC_PROGRESS = "pe"
REC_SNAPSHOT = "snap"
REC_CHUNK = "chk"
REC_PE_CHUNK = "pec"
# fault-injection marker (repro.faults): one record per (exchange,
# active fault spec), annotation-only — replay derives nothing from it
# (the faulted op stream itself is what post/arr records carry), so
# every replayer/converter passes it through untouched and the
# v2 <-> v3 byte-identity rule is preserved (flt records are never
# chunked)
REC_FAULT = "flt"

# required fields per record type (beyond "t")
_REQUIRED = {
    REC_POST: ("rank", "src", "tag", "seq"),
    REC_ARRIVE: ("rank", "src", "tag", "seq"),
    REC_PHASE: ("op", "label"),
    REC_PROGRESS: ("ev", "ts"),
    REC_SNAPSHOT: ("stats",),
    REC_CHUNK: ("n", "p", "r", "s", "g"),
    REC_PE_CHUNK: ("n", "e", "s"),
    REC_FAULT: ("kind",),
}


class TraceSchemaError(ValueError):
    """A trace file does not conform to the schema this reader speaks."""


class TraceFormatError(TraceSchemaError):
    """A trace file is malformed at a specific line: truncated or corrupt
    JSON, an unsupported version, or an invalid record/chunk shape. The
    reader raises this (with ``path`` and 1-based ``line``) instead of
    letting ``json.JSONDecodeError`` / bare ``ValueError`` leak
    mid-stream; it subclasses :class:`TraceSchemaError` so existing
    handlers keep working."""

    def __init__(self, message: str, path: Optional[str] = None,
                 line: Optional[int] = None):
        where = f"{path or '<trace>'}:{line if line is not None else '?'}"
        super().__init__(f"{where}: {message}")
        self.path = path
        self.line = line


def make_header(mode: str, meta: Optional[Dict] = None,
                schema: int = SCHEMA_VERSION) -> Dict:
    return {"t": REC_HEADER, "format": TRACE_FORMAT,
            "schema": schema, "mode": mode, "meta": meta or {}}


def validate_header(rec: Dict) -> Dict:
    if rec.get("t") != REC_HEADER:
        raise TraceSchemaError(
            f"first record must be a {REC_HEADER!r} header, got "
            f"{rec.get('t')!r}")
    if rec.get("format") != TRACE_FORMAT:
        raise TraceSchemaError(
            f"not a {TRACE_FORMAT} trace (format={rec.get('format')!r})")
    if rec.get("schema") not in SUPPORTED_VERSIONS:
        raise TraceSchemaError(
            f"unsupported schema version {rec.get('schema')!r} "
            f"(this reader speaks versions {SUPPORTED_VERSIONS})")
    return rec


_REQUIRED_SETS = {kind: frozenset(fields)
                  for kind, fields in _REQUIRED.items()}


def validate_record(rec: Dict) -> Dict:
    kind = rec.get("t")
    req = _REQUIRED_SETS.get(kind)
    if req is None:
        raise TraceSchemaError(f"unknown record type {kind!r}")
    # one C-level subset check per record on the happy path; the field
    # list is only reconstructed to name what's missing
    if not req <= rec.keys():
        missing = [f for f in _REQUIRED[kind] if f not in rec]
        raise TraceSchemaError(
            f"{kind!r} record missing required field(s) {missing}")
    return rec


# -- v3 column codec -------------------------------------------------------

def encode_ints(values: List[int]):
    """Encode one integer column: a bare int when the column is constant
    (run-length on constant columns), else the delta list
    ``[v0, v1-v0, ...]``. Inverse of :func:`decode_ints`."""
    first = values[0]
    out = [first]
    prev = first
    constant = True
    for v in values[1:]:
        out.append(v - prev)
        constant = constant and v == prev
        prev = v
    return first if constant else out


def decode_ints(enc, n: int, name: str = "column") -> List[int]:
    """Expand one encoded integer column back to its ``n`` row values."""
    if type(enc) is list:
        if len(enc) != n:
            raise TraceSchemaError(
                f"chunk {name} column has {len(enc)} entries for "
                f"{n} rows")
        return list(accumulate(enc))
    if type(enc) is not int:
        raise TraceSchemaError(
            f"chunk {name} column must be an int or a delta list, "
            f"got {type(enc).__name__}")
    return [enc] * n


def encode_outcomes(values: List[Optional[int]]):
    """Encode one nullable outcome column (``hit``/``match``): ``None``
    when every row is null, else the raw value list (outcomes are
    recorded seqs with null gaps — deltas would not round-trip)."""
    for v in values:
        if v is not None:
            return list(values)
    return None


def decode_outcomes(enc, n: int, name: str = "outcome"
                    ) -> List[Optional[int]]:
    if enc is None:
        return [None] * n
    if type(enc) is not list or len(enc) != n:
        raise TraceSchemaError(
            f"chunk {name} column must be null or a {n}-entry list")
    return enc


def encode_flags(values: List[int]):
    """Encode the is-post column: a bare int when uniform, else
    run-length pairs ``[v0, n0, v1, n1, ...]`` (an op stream is runs of
    posts and runs of arrivals — pairs beat per-row deltas)."""
    first = values[0]
    out: List[int] = []
    run_v, run_n = first, 0
    uniform = True
    for v in values:
        if v == run_v:
            run_n += 1
        else:
            out += (run_v, run_n)
            run_v, run_n = v, 1
            uniform = False
    if uniform:
        return first
    out += (run_v, run_n)
    return out


def decode_flags(enc, n: int) -> List[int]:
    """Expand the is-post column back to one 0/1 flag per row."""
    if type(enc) is int:
        if enc not in (0, 1):
            raise TraceSchemaError(f"chunk p flag must be 0 or 1, "
                                   f"got {enc!r}")
        return [enc] * n
    if type(enc) is not list or len(enc) % 2:
        raise TraceSchemaError(
            "chunk p column must be an int or [value, run, ...] pairs")
    out: List[int] = []
    it = iter(enc)
    for v, run in zip(it, it):
        if v not in (0, 1) or type(run) is not int or run < 1:
            raise TraceSchemaError(
                f"invalid chunk p run ({v!r}, {run!r})")
        out += [v] * run
    if len(out) != n:
        raise TraceSchemaError(
            f"chunk p runs cover {len(out)} rows, chunk has {n}")
    return out


def decode_chunk(rec: Dict, seqs: Optional[Dict[int, int]] = None
                 ) -> List[Dict]:
    """Expand a validated ``chk`` record into its per-op records (exact
    v2 key order, ``t_wall`` last when present). ``seqs`` is the
    per-rank next-seq counter threaded across the stream by the caller
    (:class:`repro.trace.io.TraceReader`); it is updated in place. With
    ``seqs=None`` a fresh numbering starts at this chunk — only correct
    for a chunk inspected in isolation."""
    n = rec["n"]
    if type(n) is not int or n < 1:
        raise TraceSchemaError(f"chunk row count must be a positive int, "
                               f"got {n!r}")
    if seqs is None:
        seqs = {}
    try:
        flags = decode_flags(rec["p"], n)
        ranks = decode_ints(rec["r"], n, "r")
        srcs = decode_ints(rec["s"], n, "s")
        tags = decode_ints(rec["g"], n, "g")
    except KeyError as e:
        raise TraceSchemaError(f"chunk missing column {e.args[0]!r}") \
            from None
    comms = decode_ints(rec.get("c", 0), n, "c")
    n_post = sum(flags)
    n_arr = n - n_post
    nbs = iter(decode_ints(rec.get("b", 0), n_arr, "b") if n_arr
               else ())
    hits = iter(decode_outcomes(rec.get("h"), n_post, "h"))
    matches = iter(decode_outcomes(rec.get("m"), n_arr, "m"))
    tws = (iter(decode_ints(rec["w"], n, "w")) if "w" in rec
           else None)
    out: List[Dict] = []
    for p, r, s, g, c in zip(flags, ranks, srcs, tags, comms):
        q = seqs.get(r, 0)
        seqs[r] = q + 1
        if p:
            op = {"t": REC_POST, "rank": r, "src": s, "tag": g,
                  "comm": c, "seq": q, "hit": next(hits)}
        else:
            op = {"t": REC_ARRIVE, "rank": r, "src": s, "tag": g,
                  "comm": c, "nb": next(nbs), "seq": q,
                  "match": next(matches)}
        if tws is not None:
            op["t_wall"] = next(tws)
        out.append(op)
    return out


def decode_pe_chunk(rec: Dict) -> List[Dict]:
    """Expand a validated ``pec`` record into its per-event ``pe``
    records (exact v2 key order: ``t``, ``ev``, ``ts``, then ``wait`` or
    ``dur``, ``t_wall`` last when present). Progress records carry no
    seq, so no cross-chunk state is threaded."""
    n = rec["n"]
    if type(n) is not int or n < 1:
        raise TraceSchemaError(f"pe chunk row count must be a positive "
                               f"int, got {n!r}")
    flags = decode_flags(rec["e"], n)
    tss = decode_ints(rec["s"], n, "s")
    n_sub = sum(flags)
    n_proc = n - n_sub
    waits = iter(decode_ints(rec.get("u", 0), n_sub, "u") if n_sub
                 else ())
    durs = iter(decode_ints(rec.get("d", 0), n_proc, "d") if n_proc
                else ())
    tws = (iter(decode_ints(rec["w"], n, "w")) if "w" in rec
           else None)
    out: List[Dict] = []
    for e, ts in zip(flags, tss):
        if e:
            op = {"t": REC_PROGRESS, "ev": "submit", "ts": ts,
                  "wait": next(waits)}
        else:
            op = {"t": REC_PROGRESS, "ev": "proc", "ts": ts,
                  "dur": next(durs)}
        if tws is not None:
            op["t_wall"] = next(tws)
        out.append(op)
    return out
