"""Versioned record schema for ``repro.trace`` JSONL traces.

A trace is one JSON object per line. The first line is the header; every
following line is a record tagged by its ``"t"`` field:

  ``hdr``    header: ``format``, ``schema`` (version), the engine ``mode``
             the run was recorded under, free-form ``meta``.
  ``post``   MPI_Irecv analog on one rank: envelope (``src``/``tag``/
             ``comm``), the per-engine sequence number ``seq``, and the
             match outcome ``hit`` (seq of the unexpected message the
             receive pulled from the UMQ, or null).
  ``arr``    network delivery on one rank: envelope plus payload size
             ``nb``, ``seq``, and outcome ``match`` (seq of the posted
             receive the message matched, or null -> parked on the UMQ).
  ``phase``  phase marker: ``op`` (collective kind or ``"phase"`` for
             explicit markers), human ``label``, optional attrs (``n``,
             ``nb``, ``tag``). The replayer snapshots counters at every
             marker — this is the alignment unit the differ works in.
  ``pe``     progress-engine lane event: ``ev`` = ``submit`` (``ts``,
             lock ``wait``) or ``proc`` (``ts``, processing ``dur``),
             nanosecond timestamps.
  ``snap``   counter snapshot: per-pid ``stats`` in the
             :meth:`repro.core.counters.CounterStat.to_attrs` encoding.

Version history:

  * **v1** — the record types above, no per-op timing.
  * **v2** — ``post``/``arr``/``pe`` records may carry ``t_wall``:
    live wall-clock nanoseconds since the writer opened, stamped by
    :class:`repro.trace.io.TraceWriter` (``wall_clock=True``, the
    default). Optional — a writer in deterministic mode omits it, and
    v1 traces never have it — so readers treat it as advisory timing
    (the replayer surfaces it as measured per-phase wall time /
    dilation).

Schema changes MUST bump :data:`SCHEMA_VERSION`; readers accept every
version in :data:`SUPPORTED_VERSIONS` (currently v1 and v2 — v2 only
adds an optional field) and reject anything newer
(``scripts/verify.sh`` gates on this round-tripping).
"""
from __future__ import annotations

from typing import Dict, Optional

SCHEMA_VERSION = 2
SUPPORTED_VERSIONS = (1, 2)
TRACE_FORMAT = "repro.trace"

REC_HEADER = "hdr"
REC_POST = "post"
REC_ARRIVE = "arr"
REC_PHASE = "phase"
REC_PROGRESS = "pe"
REC_SNAPSHOT = "snap"

# required fields per record type (beyond "t")
_REQUIRED = {
    REC_POST: ("rank", "src", "tag", "seq"),
    REC_ARRIVE: ("rank", "src", "tag", "seq"),
    REC_PHASE: ("op", "label"),
    REC_PROGRESS: ("ev", "ts"),
    REC_SNAPSHOT: ("stats",),
}


class TraceSchemaError(ValueError):
    """A trace file does not conform to the schema this reader speaks."""


def make_header(mode: str, meta: Optional[Dict] = None) -> Dict:
    return {"t": REC_HEADER, "format": TRACE_FORMAT,
            "schema": SCHEMA_VERSION, "mode": mode, "meta": meta or {}}


def validate_header(rec: Dict) -> Dict:
    if rec.get("t") != REC_HEADER:
        raise TraceSchemaError(
            f"first record must be a {REC_HEADER!r} header, got "
            f"{rec.get('t')!r}")
    if rec.get("format") != TRACE_FORMAT:
        raise TraceSchemaError(
            f"not a {TRACE_FORMAT} trace (format={rec.get('format')!r})")
    if rec.get("schema") not in SUPPORTED_VERSIONS:
        raise TraceSchemaError(
            f"unsupported schema version {rec.get('schema')!r} "
            f"(this reader speaks versions {SUPPORTED_VERSIONS})")
    return rec


def validate_record(rec: Dict) -> Dict:
    kind = rec.get("t")
    if kind not in _REQUIRED:
        raise TraceSchemaError(f"unknown record type {kind!r}")
    missing = [f for f in _REQUIRED[kind] if f not in rec]
    if missing:
        raise TraceSchemaError(
            f"{kind!r} record missing required field(s) {missing}")
    return rec
