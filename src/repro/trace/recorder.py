"""Recorder: hook the comm layer and the matching fabric, persist a trace.

Two entry points, both context managers yielding the traced
:class:`repro.match.Fabric`:

  * :func:`record_fabric` — trace a fabric driven directly (benchmarks,
    offline workloads; no JAX involved).
  * :func:`record_collectives` — additionally install the fabric on the
    comm layer (:func:`repro.comm.collectives.configure_matching`), so
    every ``psum`` / ``all_gather`` / ``ppermute`` a shard_map program
    dispatches — including the ring schedules and halo faces that route
    through them — is decomposed, matched *and recorded*.

On exit both write a final counter ``snap`` record (the record-time
ground truth replays are checked against) and close the file. The
progress engine is traced by passing the same writer to
``ProgressEngine(trace=writer)`` — its submit/process lane events land in
the same trace and replay under either queue discipline.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Iterator, Optional

from ..core.counters import CounterRegistry
from ..match import Fabric, canonical_mode
from .io import TraceWriter


@contextlib.contextmanager
def record_fabric(path: str, mode: str = "binned",
                  registry: Optional[CounterRegistry] = None,
                  meta: Optional[Dict] = None, wall_clock: bool = True,
                  buffer_records: Optional[int] = None,
                  schema: Optional[int] = None,
                  **fabric_kwargs) -> Iterator[Fabric]:
    """Yield a fabric whose every engine op and collective phase is
    appended to the JSONL trace at ``path``. Emission is buffered
    (``buffer_records``, default :data:`repro.trace.io.BUFFER_RECORDS`);
    everything is flushed by the final snapshot + close on exit — call
    ``fabric.trace.flush()`` mid-run if another process tails the file.
    ``wall_clock=False`` records in deterministic (byte-reproducible)
    mode; ``schema`` picks the trace encoding (3 = compact chunks, the
    default; 2 = the per-op pre-compaction format)."""
    reg = registry if registry is not None else CounterRegistry()
    writer_kwargs = {} if buffer_records is None else {
        "buffer_records": buffer_records}
    with TraceWriter(path, mode=canonical_mode(mode), meta=meta,
                     wall_clock=wall_clock, schema=schema,
                     **writer_kwargs) as writer:
        fabric = Fabric(mode=mode, registry=reg, trace=writer,
                        **fabric_kwargs)
        try:
            yield fabric
        finally:
            writer.snapshot(reg)


@contextlib.contextmanager
def record_collectives(path: str, mode: str = "binned",
                       registry: Optional[CounterRegistry] = None,
                       meta: Optional[Dict] = None, wall_clock: bool = True,
                       buffer_records: Optional[int] = None,
                       schema: Optional[int] = None,
                       **fabric_kwargs) -> Iterator[Fabric]:
    """Like :func:`record_fabric`, but also routes the live comm layer
    through the traced fabric for the duration of the block (restoring
    whatever fabric was configured before)."""
    from ..comm import collectives
    with record_fabric(path, mode=mode, registry=registry, meta=meta,
                       wall_clock=wall_clock, buffer_records=buffer_records,
                       schema=schema, **fabric_kwargs) as fabric:
        prev = collectives.matching_fabric()
        collectives.configure_matching(fabric)
        try:
            yield fabric
        finally:
            collectives.configure_matching(prev)
