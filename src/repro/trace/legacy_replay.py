"""Frozen pre-overhaul replayer — the replay bench's yardstick.

This is the trace replay path exactly as it stood before the trace
pipeline overhaul (schema v3 + batched streaming replay): the whole
record list is materialized eagerly by :func:`repro.trace.io.read_trace`
and every recorded op is re-driven through one per-op python engine call
(``post_recv``/``arrive``), with per-op match-order verification against
the recorded outcomes. The semantics are identical to the live
:class:`repro.trace.replay.Replayer` — per-phase/per-rank counter
statistics and detector findings agree cell-for-cell — only the cost
differs, which is the point: ``benchmarks/replay_bench.py`` drives every
scenario's recorded trace through both replayers *interleaved in the
same process* and gates on the throughput ratio, so the speedup
measurement is immune to machine-load swings.

Do not "fix" or optimize this module; it is a measurement reference.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple, Union

from ..core.counters import CounterRegistry, CounterStat, counter_stats
from ..core.events import Event
from ..match import MatchEngine, canonical_mode
from .io import _open
from .replay import (PHASE_NS, PhaseStats, ReplayResult, _parse_snap,
                     replay_progress)
from .schema import (_REQUIRED, REC_ARRIVE, REC_PHASE, REC_POST,
                     REC_PROGRESS, REC_SNAPSHOT, TraceSchemaError,
                     validate_header)


def _validate_record(rec: Dict) -> Dict:
    """The pre-overhaul ``validate_record``: a field-list scan per
    record (the live reader has since moved to one C-level subset
    check)."""
    kind = rec.get("t")
    if kind not in _REQUIRED:
        raise TraceSchemaError(f"unknown record type {kind!r}")
    missing = [f for f in _REQUIRED[kind] if f not in rec]
    if missing:
        raise TraceSchemaError(
            f"{kind!r} record missing required field(s) {missing}")
    return rec


def legacy_read_trace(path: str) -> Tuple[Dict, List[Dict]]:
    """The pre-overhaul eager reader: one ``json.loads`` + validation
    per line, the whole record list materialized up front. Speaks every
    per-op schema (v1/v2) — chunked v3 traces belong to the streaming
    reader this module is the yardstick for."""
    header: Optional[Dict] = None
    records: List[Dict] = []
    with _open(str(path), write=False) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if header is None:
                header = validate_header(rec)
            else:
                records.append(_validate_record(rec))
    if header is None:
        raise TraceSchemaError(f"empty trace file (no header): {path}")
    return header, records


class LegacyRegistry(CounterRegistry):
    """The pre-overhaul counter drain, frozen: per-delta double stat
    updates with three dict lookups each, dataclass-construction of
    fresh stats, and copy-then-clear snapshots. This PR's overhaul
    re-tuned all of that for replay volume (per-pid pair cache,
    columnar/distinct-value folds, zero-copy ``snapshot_lanes``), so
    the yardstick carries its own copy — the same treatment
    ``match/legacy.py`` gave the engine."""

    def _merge(self, flat) -> None:
        merged = self._merged
        by_pid = self._merged_by_pid
        it = iter(flat)
        for pid, name, value, obs in zip(it, it, it, it):
            if type(obs) is str:          # column record: name=spec,
                per = by_pid.get(pid)     # value=row-major values
                if per is None:
                    per = by_pid[pid] = {}
                cols = []
                for cname, cobs in name:
                    st = merged.get(cname)
                    if st is None:
                        st = merged[cname] = CounterStat(name=cname)
                    pst = per.get(cname)
                    if pst is None:
                        pst = per[cname] = CounterStat(name=cname)
                    cols.append((st, pst, cobs))
                k = len(cols)
                i = 0
                for v in value:
                    st, pst, cobs = cols[i]
                    i += 1
                    if i == k:
                        i = 0
                    st.count += 1
                    st.total += v
                    pst.count += 1
                    pst.total += v
                    if cobs:
                        iv = int(v)
                        b = 1 << (iv.bit_length() - 1) if iv > 0 else 0
                        st.kind = "histogram"
                        if v < st.vmin:
                            st.vmin = v
                        if v > st.vmax:
                            st.vmax = v
                        bins = st.bins
                        bins[b] = bins.get(b, 0) + 1
                        pst.kind = "histogram"
                        if v < pst.vmin:
                            pst.vmin = v
                        if v > pst.vmax:
                            pst.vmax = v
                        bins = pst.bins
                        bins[b] = bins.get(b, 0) + 1
                continue
            st = merged.get(name)
            if st is None:
                st = merged[name] = CounterStat(name=name)
            per = by_pid.get(pid)
            if per is None:
                per = by_pid[pid] = {}
            pst = per.get(name)
            if pst is None:
                pst = per[name] = CounterStat(name=name)
            st.count += 1
            st.total += value
            pst.count += 1
            pst.total += value
            if obs:
                v = int(value)
                b = 1 << (v.bit_length() - 1) if v > 0 else 0
                st.kind = "histogram"
                if value < st.vmin:
                    st.vmin = value
                if value > st.vmax:
                    st.vmax = value
                bins = st.bins
                bins[b] = bins.get(b, 0) + 1
                pst.kind = "histogram"
                if value < pst.vmin:
                    pst.vmin = value
                if value > pst.vmax:
                    pst.vmax = value
                bins = pst.bins
                bins[b] = bins.get(b, 0) + 1

    def snapshot_lanes(self) -> Dict[int, Dict[str, CounterStat]]:
        # pre-overhaul form: drain_lanes copies every lane dict, then
        # the merged aggregates are cleared
        lanes = self.drain_lanes()
        with self._registry_lock:
            self._merged = {}
            self._merged_by_pid = {}
        return lanes


class LegacyReplayer:
    """Pre-overhaul replay: eager record list, one python dispatch per
    recorded op. Same constructor contract as the pre-overhaul
    ``Replayer`` (mode / progress_mode / phase_ns)."""

    def __init__(self, mode: Optional[str] = None,
                 progress_mode: Optional[str] = None,
                 phase_ns: int = PHASE_NS):
        self.mode = mode
        self.progress_mode = progress_mode
        self.phase_ns = phase_ns

    def run(self, source: Union[str, Tuple[Dict, List[Dict]]]
            ) -> ReplayResult:
        if isinstance(source, (tuple, list)):
            header, records = source
        else:
            header, records = legacy_read_trace(source)
        mode = canonical_mode(self.mode or header.get("mode", "binned"))

        registry = LegacyRegistry()
        engines: Dict[int, MatchEngine] = {}

        def engine(rank: int) -> MatchEngine:
            eng = engines.get(rank)
            if eng is None:
                eng = engines[rank] = MatchEngine(
                    rank=rank, mode=mode, registry=registry.lane(rank))
            return eng

        phases: List[PhaseStats] = []
        events: List[Event] = []
        matches: List[Tuple[int, str, int, Optional[int]]] = []
        divergences: List[Dict] = []
        pe_records: List[Dict] = []
        recorded_stats: Optional[Dict[int, Dict[str, CounterStat]]] = None
        current = PhaseStats(index=0, label="prologue", op="phase")
        wall: List[int] = []          # t_wall stamps seen in current phase

        def flush_phase() -> None:
            t = (len(phases) + 1) * self.phase_ns
            evs = registry.snapshot_events(t_ns=t)
            per: Dict[int, List[Event]] = {}
            for ev in evs:
                ev.attrs["phase"] = current.label
                ev.attrs["phase_index"] = current.index
                per.setdefault(ev.pid, []).append(ev)
            current.stats = {pidx: counter_stats(group)
                             for pidx, group in per.items()}
            if wall:
                current.wall_ns = max(wall) - min(wall)
                del wall[:]
            phases.append(current)
            events.extend(evs)

        for rec in records:
            kind = rec["t"]
            if "t_wall" in rec:
                wall.append(rec["t_wall"])
            if kind == REC_PHASE:
                flush_phase()
                current = PhaseStats(
                    index=len(phases), label=rec["label"], op=rec["op"],
                    attrs={k: v for k, v in rec.items()
                           if k not in ("t", "op", "label")})
            elif kind == REC_POST:
                r = engine(rec["rank"]).post_recv(
                    src=rec["src"], tag=rec["tag"], comm=rec.get("comm", 0))
                got = r.message.seq if r.message is not None else None
                matches.append((rec["rank"], "post", r.seq, got))
                if "hit" in rec and rec["hit"] != got:
                    divergences.append(
                        {"rec": rec, "replayed": got, "mode": mode})
            elif kind == REC_ARRIVE:
                r = engine(rec["rank"]).arrive(
                    src=rec["src"], tag=rec["tag"],
                    comm=rec.get("comm", 0), nbytes=rec.get("nb", 0))
                got = r.seq if r is not None else None
                matches.append((rec["rank"], "arr", rec["seq"], got))
                if "match" in rec and rec["match"] != got:
                    divergences.append(
                        {"rec": rec, "replayed": got, "mode": mode})
            elif kind == REC_PROGRESS:
                pe_records.append(rec)
            elif kind == REC_SNAPSHOT:
                recorded_stats = _parse_snap(rec)
        flush_phase()

        progress_mode = self.progress_mode
        progress_events: List[Event] = []
        if pe_records:
            progress_mode = progress_mode or "incoming"
            progress_events = replay_progress(pe_records, progress_mode)
            events.extend(progress_events)

        return ReplayResult(
            mode=mode, progress_mode=progress_mode, header=header,
            matches=matches, divergences=divergences, phases=phases,
            events=events, progress_events=progress_events,
            pe_records=pe_records, registry=registry,
            recorded_stats=recorded_stats, n_ops=len(matches))


def legacy_replay(source: Union[str, Tuple[Dict, List[Dict]]],
                  mode: Optional[str] = None,
                  progress_mode: Optional[str] = None) -> ReplayResult:
    """One-call frozen replay: ``legacy_replay(path, mode="linear")``."""
    return LegacyReplayer(mode=mode, progress_mode=progress_mode
                          ).run(source)
