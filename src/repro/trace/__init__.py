# Communication trace capture + deterministic what-if replay: record the
# matching fabric's post/arrive stream (and the progress engine's lane
# events) to a versioned JSONL trace once, then re-drive it offline
# through any engine configuration — counters, detectors and the trace
# differ all run on replayed data, no workload re-execution needed.
from .diff import PhaseDelta, TraceDiff, diff
from .io import TraceWriter, read_trace
from .recorder import record_collectives, record_fabric
from .replay import (LOCK_REGION, PhaseStats, Replayer, ReplayResult,
                     replay, replay_progress)
from .schema import (SCHEMA_VERSION, SUPPORTED_VERSIONS, TRACE_FORMAT,
                     TraceSchemaError, make_header, validate_header,
                     validate_record)

__all__ = [
    "PhaseDelta", "TraceDiff", "diff",
    "TraceWriter", "read_trace",
    "record_collectives", "record_fabric",
    "LOCK_REGION", "PhaseStats", "Replayer", "ReplayResult", "replay",
    "replay_progress",
    "SCHEMA_VERSION", "SUPPORTED_VERSIONS", "TRACE_FORMAT",
    "TraceSchemaError", "make_header", "validate_header",
    "validate_record",
]
