# Communication trace capture + deterministic what-if replay: record the
# matching fabric's post/arrive stream (and the progress engine's lane
# events) to a versioned JSONL trace once — compact columnar chunks at
# schema v3 — then re-drive it offline through any engine configuration
# (streaming + batched, or per-op with match-order verification) —
# counters, detectors and the trace differ all run on replayed data, no
# workload re-execution needed.
from .diff import PhaseDelta, TraceDiff, diff
from .io import (TraceCorruptionWarning, TraceReader, TraceWriter,
                 convert_trace, iter_trace, read_trace)
from .legacy_replay import LegacyReplayer, legacy_replay
from .recorder import record_collectives, record_fabric
from .replay import (LOCK_REGION, PartitionScan, PhaseStats, Replayer,
                     ReplayResult, replay, replay_progress,
                     scan_partition)
from .schema import (SCHEMA_VERSION, SUPPORTED_VERSIONS, TRACE_FORMAT,
                     WRITABLE_VERSIONS, TraceFormatError,
                     TraceSchemaError, decode_chunk, decode_pe_chunk,
                     make_header, validate_header, validate_record)

__all__ = [
    "PhaseDelta", "TraceDiff", "diff",
    "TraceCorruptionWarning", "TraceReader", "TraceWriter",
    "convert_trace", "iter_trace", "read_trace",
    "LegacyReplayer", "legacy_replay",
    "record_collectives", "record_fabric",
    "LOCK_REGION", "PartitionScan", "PhaseStats", "Replayer",
    "ReplayResult", "replay", "replay_progress", "scan_partition",
    "SCHEMA_VERSION", "SUPPORTED_VERSIONS", "TRACE_FORMAT",
    "WRITABLE_VERSIONS", "TraceFormatError", "TraceSchemaError",
    "decode_chunk", "decode_pe_chunk", "make_header", "validate_header",
    "validate_record",
]
