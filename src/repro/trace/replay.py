"""Deterministic what-if replay of recorded communication traces.

A recorded trace is an ordered stream of matching-engine operations
(post/arrive with envelopes), phase markers and progress-engine lane
events. Replay re-drives that exact stream through a *fresh* set of
engines in any mode (``binned``/``fifo``, ``linear``, ``leaky_umq``) —
no JAX, no re-execution of the workload — and produces the same
artifacts a live run produces:

  * per-rank, per-phase counter statistics (one registry lane per rank),
  * ``core.counters`` snapshot Events (category ``"counter"``) at every
    phase boundary, so ``long_traversal`` / ``umq_flood`` and the rest of
    :mod:`repro.core.analyses` run on replayed data unchanged,
  * modeled progress-engine lock Events under either queue discipline
    (the §4 shared-queue defect vs the incoming-queue fix), so
    ``contention`` runs on replayed data too.

Because the seeded defects change *cost*, never *matching* (the
engine-mode equivalence property ``tests/test_match.py`` pins down),
replaying under a different mode answers "what would this exact run have
cost on that engine?" — and replaying under the same mode reproduces the
recorded match order exactly (``divergences`` stays empty).

Two execution paths share one result type:

  * ``check_matches=True`` (the default) — per-op dispatch with match-
    order verification: every recorded outcome is compared against the
    replayed one and ``matches``/``divergences`` are populated. This is
    the soundness path the acceptance sweeps gate.
  * ``check_matches=False`` — the **batched streaming** path (the trace-
    pipeline overhaul): records stream straight off the reader, v3
    chunks are decoded column-wise into flat per-rank op streams and
    dispatched through :meth:`repro.match.MatchEngine.run_ops` at every
    phase boundary (one python call per rank per phase, the PR 4
    columnar counter sink underneath), so the full record list is never
    materialized and per-op python dispatch disappears. Counter
    statistics, phases and findings are identical — pinned against the
    frozen pre-overhaul replayer (:mod:`repro.trace.legacy_replay`) by
    ``benchmarks/replay_bench.py``, which also gates the >= 5x
    throughput this path exists for.
"""
from __future__ import annotations

import dataclasses
from itertools import accumulate, repeat
from typing import (Dict, FrozenSet, Iterable, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from ..core.counters import CounterRegistry, CounterStat, counter_stats
from ..core.events import Event
from ..match import MatchEngine, canonical_mode
from .io import TraceReader, iter_trace
from .schema import (REC_ARRIVE, REC_CHUNK, REC_PE_CHUNK, REC_PHASE,
                     REC_POST, REC_PROGRESS, REC_SNAPSHOT, decode_chunk,
                     decode_flags, decode_pe_chunk)

# mirrors repro.comm.progress.LOCK_REGION without importing the comm layer
# (which would pull in JAX — replay stays JAX-free)
LOCK_REGION = "BlockingProgress lock"

# synthetic spacing between phase snapshots on the replay timeline
PHASE_NS = 1_000_000


@dataclasses.dataclass
class PhaseStats:
    """Counter deltas attributed to one recorded phase, per rank.

    ``wall_ns`` is the measured live wall-clock span of the phase's
    recorded ops (schema v2+ ``t_wall`` stamps); ``None`` for v1 traces
    or deterministic-mode recordings."""

    index: int
    label: str
    op: str
    attrs: Dict = dataclasses.field(default_factory=dict)
    stats: Dict[int, Dict[str, CounterStat]] = dataclasses.field(
        default_factory=dict)
    wall_ns: Optional[int] = None

    def metric(self, rank: int, name: str) -> Optional[CounterStat]:
        return self.stats.get(rank, {}).get(name)


class ReplayResult:
    """Everything one replay produced. ``events`` (the counter snapshot
    Events plus modeled progress-lane Events the detectors consume) is
    **materialized lazily** from the per-phase lane statistics: the
    batched streaming path never pays the Event + attrs encode cost for
    consumers that only read ``phases`` (the differ, the bench gates) —
    accessing ``.events`` builds the identical event list the eager
    per-op path would have produced."""

    def __init__(self, mode: str, progress_mode: Optional[str],
                 header: Dict,
                 matches: List[Tuple[int, str, int, Optional[int]]],
                 divergences: List[Dict], phases: List[PhaseStats],
                 registry: CounterRegistry,
                 events: Optional[List[Event]] = None,
                 progress_events: Optional[List[Event]] = None,
                 pe_records: Optional[List[Dict]] = None,
                 recorded_stats: Optional[
                     Dict[int, Dict[str, CounterStat]]] = None,
                 raw_snap: Optional[Dict] = None,
                 n_ops: int = 0, phase_ns: int = PHASE_NS,
                 skipped_records: Optional[Dict[str, int]] = None):
        self.mode = mode
        self.progress_mode = progress_mode
        self.header = header
        self.matches = matches
        self.divergences = divergences
        self.phases = phases
        self.registry = registry
        # per-category tally of corrupt source lines a lenient
        # (strict=False) reader dropped before replay saw them
        self.skipped_records: Dict[str, int] = skipped_records or {}
        # engine ops replayed; on the batched path (check_matches=False)
        # ``matches`` stays empty, so this is the op count to report
        self.n_ops = n_ops
        self.phase_ns = phase_ns
        self._events = events
        self._pe_records = pe_records or []
        # eager results pass the modeled progress events in (they are
        # also inside `events` already); lazy ones model them on demand
        # from the pe records
        self._progress_events: Optional[List[Event]] = (
            (progress_events or []) if events is not None else None)
        self._recorded_stats = recorded_stats
        self._raw_snap = raw_snap

    @property
    def recorded_stats(self) -> Optional[
            Dict[int, Dict[str, CounterStat]]]:
        """The record-time final counter snapshot (the trace's ``snap``
        record), parsed on first access."""
        if self._recorded_stats is None and self._raw_snap is not None:
            self._recorded_stats = _parse_snap(self._raw_snap)
            self._raw_snap = None
        return self._recorded_stats

    @property
    def pe_records(self) -> List[Dict]:
        """The recorded progress-engine lane records (expanded), as fed
        to :func:`replay_progress` — the transportable form sharded
        replay merges across workers."""
        return self._pe_records

    @property
    def raw_snapshot(self) -> Optional[Dict]:
        """The unparsed final ``snap`` record, if the trace carried one
        and :attr:`recorded_stats` has not consumed it yet."""
        return self._raw_snap

    @property
    def progress_events(self) -> List[Event]:
        """Modeled progress-engine lock Events (lazy: the queue model
        only runs when something consumes the events)."""
        ev = self._progress_events
        if ev is None:
            ev = self._progress_events = (
                replay_progress(self._pe_records, self.progress_mode)
                if self._pe_records and self.progress_mode else [])
        return ev

    @property
    def events(self) -> List[Event]:
        ev = self._events
        if ev is None:
            ev = self._events = (self._phase_events()
                                 + self.progress_events)
        return ev

    def _phase_events(self) -> List[Event]:
        """Counter snapshot Events rebuilt from the per-phase lane stats
        (same names, paths, timestamps, attrs and ordering as
        :meth:`repro.core.counters.CounterRegistry.snapshot_events` at
        every phase flush)."""
        from ..core.counters import COUNTER_CATEGORY, COUNTER_PREFIX
        out: List[Event] = []
        for phase in self.phases:
            t = (phase.index + 1) * self.phase_ns
            for pid in sorted(phase.stats):
                per = phase.stats[pid]
                for name in sorted(per):
                    attrs = per[name].to_attrs()
                    attrs["phase"] = phase.label
                    attrs["phase_index"] = phase.index
                    out.append(Event(
                        name=COUNTER_PREFIX + name,
                        path=("counters",) + tuple(name.split(".")),
                        category=COUNTER_CATEGORY, t_start=t, t_end=t,
                        pid=pid, tid=0, attrs=attrs))
        return out

    def totals(self) -> Dict[str, CounterStat]:
        """Replayed counter statistics aggregated across ranks."""
        return counter_stats(self.events)

    def measured_wall_s(self) -> Optional[float]:
        """Total measured live wall time across phases (v2+ ``t_wall``
        stamps), or ``None`` when the trace carries no timing (v1, or
        recorded in deterministic mode)."""
        spans = [p.wall_ns for p in self.phases if p.wall_ns is not None]
        return sum(spans) / 1e9 if spans else None

    def dilation(self, baseline: "ReplayResult") -> Optional[float]:
        """Measured wall-time dilation of this trace's live run relative
        to ``baseline``'s (e.g. a defective recording vs a healthy one).
        ``None`` unless both traces carry ``t_wall`` timing."""
        a, b = baseline.measured_wall_s(), self.measured_wall_s()
        if a is None or b is None or a <= 0:
            return None
        return b / a

    def totals_by_rank(self) -> Dict[int, Dict[str, CounterStat]]:
        per: Dict[int, List[Event]] = {}
        for ev in self.events:
            per.setdefault(ev.pid, []).append(ev)
        return {pid: counter_stats(evs) for pid, evs in per.items()}


def _parse_snap(rec: Dict) -> Dict[int, Dict[str, CounterStat]]:
    out: Dict[int, Dict[str, CounterStat]] = {}
    for pid, per in rec["stats"].items():
        out[int(pid)] = {name: CounterStat.from_attrs(attrs)
                         for name, attrs in per.items()}
    return out


def _expand_stream(records: Iterable[Dict]) -> Iterable[Dict]:
    """Expand v3 chunks inline (threading the per-rank derived-seq
    counters) so the per-op verification path sees the per-op record
    stream regardless of how the source was read."""
    seqs: Dict[int, int] = {}
    for rec in records:
        kind = rec.get("t")
        if kind == REC_CHUNK:
            yield from decode_chunk(rec, seqs)
            continue
        if kind == REC_PE_CHUNK:
            yield from decode_pe_chunk(rec)
            continue
        if kind == REC_POST or kind == REC_ARRIVE:
            rank, seq = rec.get("rank"), rec.get("seq")
            if type(rank) is int and type(seq) is int:
                seqs[rank] = seq + 1
        yield rec


def replay_progress(pe_records: Sequence[Dict], mode: str = "incoming",
                    pid: int = 0, swap_ns: int = 1_000) -> List[Event]:
    """Re-model recorded progress-engine lane events under a queue
    discipline (deterministic queueing model over the recorded submit
    times and processing quanta):

      * ``"shared"`` — one queue: the progress thread holds the lock for
        whole processing quanta, so a submit landing inside a busy span
        waits for the span to end. Lock-hold Events overlap across
        threads, which ``core.analyses.contention`` flags — the paper's
        Fig. 8, reconstructed offline.
      * ``"incoming"`` — second queue: the lock is held only for an O(1)
        append/swap; lock Events never overlap and the timeline is clean.

    tid 0 is the user thread, tid 1 the progress thread (the same lane
    convention as the live timeline)."""
    assert mode in ("shared", "incoming")
    # concurrent submitters can win the trace-writer lock out of enqueue
    # order; ts is captured pre-lock, so sorting restores arrival order
    # before submits are paired positionally with FIFO-processed quanta
    submits = sorted((r for r in pe_records if r.get("ev") == "submit"),
                     key=lambda r: r["ts"])
    procs = sorted((r for r in pe_records if r.get("ev") == "proc"),
                   key=lambda r: r["ts"])
    if not submits or not procs:
        return []
    base = min(r["ts"] for r in submits + procs)
    events: List[Event] = []

    def lock_event(tid: int, t0: int, t1: int) -> Event:
        return Event(name=LOCK_REGION, path=("replay", LOCK_REGION),
                     category="runtime", t_start=t0, t_end=t1, pid=pid,
                     tid=tid, attrs={"lock": "request_queue",
                                     "replayed": mode})

    if mode == "shared":
        # progress thread drains back-to-back, holding the lock for whole
        # processing quanta; request i completes at C_i
        spans: List[Tuple[int, int]] = []
        completions: List[int] = []
        frontier: Optional[int] = None
        for sub, proc in zip(submits, procs):
            s = sub["ts"] - base
            start = s if frontier is None or frontier <= s else frontier
            end = start + int(proc.get("dur", 0))
            events.append(Event(
                name="progress/process", path=("replay", "progress",
                                               "process"),
                category="runtime", t_start=start, t_end=end, pid=pid,
                tid=1))
            spans.append((start, end))
            completions.append(end)
            frontier = end
        merged: List[Tuple[int, int]] = []
        for a, b in spans:
            if merged and a <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], b))
            else:
                merged.append((a, b))
        for a, b in merged:
            events.append(lock_event(1, a, b))
        # submit j blocks behind the processing of every *earlier*
        # request (the paper's Fig. 10: Isend latency grows with the
        # number of pending requests). Submits without a matching proc
        # record (engine shut down with requests still queued) block
        # behind the last *known* completion.
        for j, sub in enumerate(submits):
            s = sub["ts"] - base
            release = s + swap_ns
            if j > 0 and completions:
                release = max(release,
                              completions[min(j, len(completions)) - 1])
            events.append(lock_event(0, s, release))
    else:
        frontier = 0
        for sub, proc in zip(submits, procs):
            s = sub["ts"] - base
            events.append(lock_event(0, s, s + swap_ns))
            # instant swap on the progress thread: zero-width hold, no
            # cross-thread overlap possible
            events.append(lock_event(1, s + swap_ns, s + swap_ns))
            start = max(s + swap_ns, frontier)
            end = start + int(proc.get("dur", 0))
            events.append(Event(
                name="progress/process", path=("replay", "progress",
                                               "process"),
                category="runtime", t_start=start, t_end=end, pid=pid,
                tid=1))
            frontier = end
    events.sort(key=lambda e: (e.t_start, e.t_end))
    return events


@dataclasses.dataclass
class PartitionScan:
    """Cheap pre-scan of a trace for shard planning (no replay, no chunk
    expansion): which ranks appear and how many ops each carries, how
    many phases the stream is cut into, and the total op count."""

    header: Dict
    rank_ops: Dict[int, int]
    n_phases: int
    n_ops: int

    @property
    def ranks(self) -> List[int]:
        return sorted(self.rank_ops)


def scan_partition(source: Union[str, TraceReader]) -> PartitionScan:
    """Scan a trace once (raw chunks, columns never expanded) and return
    the partitionable structure :func:`repro.corpus.parallel_replay`
    plans shards from."""
    reader = (source if isinstance(source, TraceReader)
              else iter_trace(str(source), expand=False))
    rank_ops: Dict[int, int] = {}
    n_phases = 1
    n_ops = 0
    for rec in reader:
        kind = rec["t"]
        if kind == REC_CHUNK:
            n = rec["n"]
            n_ops += n
            r = rec["r"]
            if type(r) is int:
                rank_ops[r] = rank_ops.get(r, 0) + n
            else:
                vals, counts = np.unique(
                    np.cumsum(np.asarray(r, dtype=np.int64)),
                    return_counts=True)
                for rank, cnt in zip(vals.tolist(), counts.tolist()):
                    rank_ops[rank] = rank_ops.get(rank, 0) + cnt
        elif kind == REC_POST or kind == REC_ARRIVE:
            n_ops += 1
            rank = rec["rank"]
            rank_ops[rank] = rank_ops.get(rank, 0) + 1
        elif kind == REC_PHASE:
            n_phases += 1
    return PartitionScan(header=reader.header, rank_ops=rank_ops,
                         n_phases=n_phases, n_ops=n_ops)


class Replayer:
    """Re-drive a recorded trace through an alternate engine config.

    ``mode`` overrides the engine mode (default: the recorded one);
    ``progress_mode`` picks the queue discipline for progress-engine lane
    events (default: leave them out unless the trace has any, then replay
    as ``"incoming"``). ``check_matches=False`` selects the batched
    streaming path (no per-op outcome verification — see the module
    docstring).

    The batched path can additionally replay a *partition* of the stream
    (the primitive under :mod:`repro.corpus` sharded replay):

      * ``ranks`` — replay only these ranks' ops. Sound because every
        rank's engine is fully independent: filtering is exact, not
        approximate, and the per-phase stats for the selected ranks are
        identical to a full replay's.
      * ``phase_range=(lo, hi)`` — record only phases ``lo..hi-1``.
        Engine state (UMQ leaks, posted receives) legitimately crosses
        phase boundaries, so earlier phases are still *driven* as warmup
        with counters disabled; the stream is abandoned once ``hi`` is
        reached unless the range extends to the end (the tail shard also
        owns the trailing progress records and snapshot).

    Both require ``check_matches=False`` (the verification path compares
    per-op outcomes against the full recorded stream and would report
    every filtered op as a divergence)."""

    def __init__(self, mode: Optional[str] = None,
                 progress_mode: Optional[str] = None,
                 phase_ns: int = PHASE_NS, check_matches: bool = True,
                 ranks: Optional[Iterable[int]] = None,
                 phase_range: Optional[Tuple[int, int]] = None,
                 strict: bool = True):
        self.mode = mode
        self.progress_mode = progress_mode
        self.phase_ns = phase_ns
        self.check_matches = check_matches
        # strict=False opens path sources leniently: corrupt lines are
        # skipped by the reader and tallied into the result's
        # ``skipped_records`` instead of aborting the replay
        self.strict = strict
        self.ranks: Optional[FrozenSet[int]] = (
            None if ranks is None else frozenset(ranks))
        self.phase_range = phase_range
        if check_matches and (self.ranks is not None
                              or phase_range is not None):
            raise ValueError(
                "partitioned replay (ranks/phase_range) requires "
                "check_matches=False")

    def _open(self, source
              ) -> Tuple[Dict, Iterable[Dict]]:
        """(header, record stream). Paths stream through a
        :class:`~repro.trace.io.TraceReader` (raw for the batched path,
        expanded for verification); ``(header, records)`` tuples and
        open readers are consumed as given."""
        if isinstance(source, TraceReader):
            records: Iterable[Dict] = source
            if self.check_matches and not source.expand:
                # the verifying loop speaks per-op records only — a raw
                # reader's chunks must be expanded inline
                records = _expand_stream(records)
            return source.header, records
        if isinstance(source, (tuple, list)):
            header, records = source
            if self.check_matches:
                records = _expand_stream(records)
            return header, records
        reader = iter_trace(str(source), expand=self.check_matches,
                            strict=self.strict)
        return reader.header, reader

    def run(self, source: Union[str, TraceReader,
                                Tuple[Dict, Sequence[Dict]]]
            ) -> ReplayResult:
        header, records = self._open(source)
        if self.check_matches:
            result = self._run_checked(header, records)
        else:
            result = self._run_batched(header, records)
        reader = (records if isinstance(records, TraceReader)
                  else source if isinstance(source, TraceReader)
                  else None)
        if reader is not None and reader.skipped:
            result.skipped_records = dict(reader.skipped)
        return result

    # -- per-op verification path -----------------------------------------

    def _run_checked(self, header: Dict,
                     records: Iterable[Dict]) -> ReplayResult:
        mode = canonical_mode(self.mode or header.get("mode", "binned"))

        registry = CounterRegistry()
        engines: Dict[int, MatchEngine] = {}

        def engine(rank: int) -> MatchEngine:
            eng = engines.get(rank)
            if eng is None:
                eng = engines[rank] = MatchEngine(
                    rank=rank, mode=mode, registry=registry.lane(rank))
            return eng

        phases: List[PhaseStats] = []
        events: List[Event] = []
        matches: List[Tuple[int, str, int, Optional[int]]] = []
        divergences: List[Dict] = []
        pe_records: List[Dict] = []
        recorded_stats: Optional[Dict[int, Dict[str, CounterStat]]] = None
        current = PhaseStats(index=0, label="prologue", op="phase")
        wall: List[int] = []          # t_wall stamps seen in current phase

        def flush_phase() -> None:
            t = (len(phases) + 1) * self.phase_ns
            evs = registry.snapshot_events(t_ns=t)
            per: Dict[int, List[Event]] = {}
            for ev in evs:
                ev.attrs["phase"] = current.label
                ev.attrs["phase_index"] = current.index
                per.setdefault(ev.pid, []).append(ev)
            current.stats = {pidx: counter_stats(group)
                             for pidx, group in per.items()}
            if wall:
                current.wall_ns = max(wall) - min(wall)
                del wall[:]
            phases.append(current)
            events.extend(evs)

        for rec in records:
            kind = rec["t"]
            if "t_wall" in rec:
                wall.append(rec["t_wall"])
            if kind == REC_PHASE:
                flush_phase()
                current = PhaseStats(
                    index=len(phases), label=rec["label"], op=rec["op"],
                    attrs={k: v for k, v in rec.items()
                           if k not in ("t", "op", "label")})
            elif kind == REC_POST:
                r = engine(rec["rank"]).post_recv(
                    src=rec["src"], tag=rec["tag"], comm=rec.get("comm", 0))
                got = r.message.seq if r.message is not None else None
                matches.append((rec["rank"], "post", r.seq, got))
                if "hit" in rec and rec["hit"] != got:
                    divergences.append(
                        {"rec": rec, "replayed": got, "mode": mode})
            elif kind == REC_ARRIVE:
                r = engine(rec["rank"]).arrive(
                    src=rec["src"], tag=rec["tag"],
                    comm=rec.get("comm", 0), nbytes=rec.get("nb", 0))
                got = r.seq if r is not None else None
                matches.append((rec["rank"], "arr", rec["seq"], got))
                if "match" in rec and rec["match"] != got:
                    divergences.append(
                        {"rec": rec, "replayed": got, "mode": mode})
            elif kind == REC_PROGRESS:
                pe_records.append(rec)
            elif kind == REC_SNAPSHOT:
                recorded_stats = _parse_snap(rec)
        flush_phase()

        progress_mode = self.progress_mode
        progress_events: List[Event] = []
        if pe_records:
            progress_mode = progress_mode or "incoming"
            progress_events = replay_progress(pe_records, progress_mode)
            events.extend(progress_events)

        return ReplayResult(
            mode=mode, progress_mode=progress_mode, header=header,
            matches=matches, divergences=divergences, phases=phases,
            events=events, progress_events=progress_events,
            pe_records=pe_records, registry=registry,
            recorded_stats=recorded_stats, n_ops=len(matches))

    # -- batched streaming path -------------------------------------------

    def _run_batched(self, header: Dict,
                     records: Iterable[Dict]) -> ReplayResult:
        """Decode straight into the batch engine APIs: chunk columns
        become flat ``run_ops`` quint streams per rank, dispatched once
        per (rank, phase). Recorded ``seq``/outcome columns are not even
        decoded — matching outcomes are deterministic, and the
        verification path exists when they must be re-checked."""
        mode = canonical_mode(self.mode or header.get("mode", "binned"))

        # lanes-only: every consumer of this registry reads per-rank
        # lane deltas (the per-phase snapshots); the cross-lane
        # aggregate would double the drain work unread
        registry = CounterRegistry(lanes_only=True)
        engines: Dict[int, MatchEngine] = {}

        def engine(rank: int) -> MatchEngine:
            eng = engines.get(rank)
            if eng is None:
                eng = engines[rank] = MatchEngine(
                    rank=rank, mode=mode, registry=registry.lane(rank))
            return eng

        rsel = self.ranks
        prange = self.phase_range
        lo, hi = prange if prange is not None else (0, None)
        # rec_on: current phase is inside the recorded range. Warmup
        # phases (phase partitioning) are driven with counters disabled —
        # the engine checks ``registry.enabled`` per counting site, so
        # queue state evolves identically while stats stay silent.
        rec_on = prange is None or lo <= 0
        if prange is not None:
            registry.enabled = rec_on
        stopped = False

        phases: List[PhaseStats] = []
        pe_records: List[Dict] = []
        raw_snap: Optional[Dict] = None
        pidx = 0
        current = PhaseStats(index=0, label="prologue", op="phase")
        # rank -> ordered dispatch segments, each one batch-engine call:
        #   [1, tag, comm, 0,  srcs]   post_recv_batch / post_recv
        #   [0, tag, comm, nb, srcs]   arrive_batch / arrive
        #   [3, src, comm, 0,  tags]   post_recv_tags
        #   [4, src, comm, nb, tags]   arrive_tags
        #   [2, 0,   0,    0,  quints] run_ops (mixed/varying envelope)
        pending: Dict[int, List[List]] = {}
        get_segs = pending.get
        wall_lo: Optional[int] = None    # t_wall span of current phase
        wall_hi = 0
        n_ops = 0

        def flush_ops() -> None:
            for rank in sorted(pending):
                eng = engine(rank)
                segs_r = pending[rank]
                quints: Optional[List] = None
                for kind_, a, comm_, nb_, items in segs_r:
                    if kind_ <= 1 and len(items) == 1:
                        # singleton segment (envelope changed every op —
                        # alternating-tag phases): fold runs of these
                        # into one run_ops quint stream instead of a
                        # per-op API call per segment
                        if quints is None:
                            quints = []
                        quints += (kind_, items[0], a, nb_, comm_)
                        continue
                    if quints is not None:
                        eng.run_ops(quints)
                        quints = None
                    if kind_ == 1:
                        eng.post_recv_batch(items, a, comm_)
                    elif kind_ == 0:
                        eng.arrive_batch(items, a, comm_, nb_)
                    elif kind_ == 2:
                        eng.run_ops(items)
                    elif kind_ == 3:
                        eng.post_recv_tags(a, items, comm_)
                    else:
                        eng.arrive_tags(a, items, comm_, nb_)
                if quints is not None:
                    eng.run_ops(quints)
            pending.clear()

        def flush_phase() -> None:
            # streaming flush: per-rank stats come straight off the
            # columnar counter-sink drain (snapshot_lanes) — no Event
            # materialization, no attrs round-trip; ReplayResult builds
            # the identical Events lazily if anything asks for them.
            # Warmup phases (outside phase_range) still dispatch and
            # reset the wall span, but record nothing.
            nonlocal wall_lo
            flush_ops()
            if rec_on:
                current.stats = registry.snapshot_lanes()
                if wall_lo is not None:
                    current.wall_ns = wall_hi - wall_lo
                phases.append(current)
            wall_lo = None

        for rec in records:
            kind = rec["t"]
            if kind == REC_CHUNK:
                n = rec["n"]
                w = rec.get("w")
                if w is not None:
                    # t_wall is monotone within a chunk: the span is
                    # first value .. cumulative sum of the delta list
                    if type(w) is int:
                        wlo = whi = w
                    else:
                        wlo, whi = w[0], sum(w)
                    if wall_lo is None:
                        wall_lo = wlo
                    wall_hi = whi
                p = rec["p"]
                r = rec["r"]
                if rsel is not None and type(r) is int and r not in rsel:
                    continue
                # op accounting: whole constant-rank chunks (and every
                # chunk when unfiltered) count here; rank-varying chunks
                # under a rank filter count per group below
                split_count = rsel is not None and type(r) is not int
                if rec_on and not split_count:
                    n_ops += n
                s = rec["s"]
                g = rec["g"]
                c = rec.get("c", 0)
                b = rec.get("b", 0)
                env_const = (type(g) is int and type(c) is int
                             and type(b) is int)
                if type(p) is int and type(r) is int and env_const:
                    # uniform-kind single-rank constant-envelope chunk
                    # -> one post_recv_batch/arrive_batch segment
                    segs = get_segs(r)
                    if segs is None:
                        segs = pending[r] = []
                    segs.append([p, g, c, 0 if p else b,
                                 [s] * n if type(s) is int
                                 else list(accumulate(s))])
                    continue
                if (type(p) is int and type(r) is int
                        and type(s) is int and type(c) is int
                        and type(b) is int):
                    # tag-scan chunk (fixed src, varying tags) -> one
                    # post_recv_tags/arrive_tags segment
                    segs = get_segs(r)
                    if segs is None:
                        segs = pending[r] = []
                    segs.append([3 if p else 4, s, c, 0 if p else b,
                                 list(accumulate(g))])
                    continue
                if n >= 64:
                    # large multi-rank chunk: expand columns and group
                    # rows by rank (cumsum over the delta lists, one
                    # stable argsort) at C speed
                    fa = (np.full(n, p, dtype=np.int64)
                          if type(p) is int
                          else np.asarray(decode_flags(p, n),
                                          dtype=np.int64))
                    ra = (np.full(n, r, dtype=np.int64)
                          if type(r) is int
                          else np.cumsum(np.asarray(r, dtype=np.int64)))
                    sa = (np.full(n, s, dtype=np.int64)
                          if type(s) is int
                          else np.cumsum(np.asarray(s, dtype=np.int64)))
                    order = np.argsort(ra, kind="stable")
                    sr = ra[order]
                    cuts = np.flatnonzero(sr[1:] != sr[:-1]) + 1
                    if env_const:
                        # per rank, split into kind runs -> batch
                        # segments with the src block lifted wholesale
                        for idx in np.split(order, cuts):
                            rank = int(ra[idx[0]])
                            if rsel is not None and rank not in rsel:
                                continue
                            if rec_on and split_count:
                                n_ops += len(idx)
                            segs = get_segs(rank)
                            if segs is None:
                                segs = pending[rank] = []
                            subf = fa[idx]
                            kcuts = np.flatnonzero(
                                subf[1:] != subf[:-1]) + 1
                            for ridx in (np.split(idx, kcuts)
                                         if len(kcuts) else (idx,)):
                                k_ = int(fa[ridx[0]])
                                segs.append(
                                    [k_, g, c, 0 if k_ else b,
                                     sa[ridx].tolist()])
                        continue
                    # varying envelope: quint matrix -> run_ops segment
                    m = np.empty((n, 5), dtype=np.int64)
                    m[:, 0] = fa
                    m[:, 1] = sa
                    m[:, 2] = (g if type(g) is int
                               else np.cumsum(np.asarray(
                                   g, dtype=np.int64)))
                    if type(b) is int:
                        m[:, 3] = np.where(fa == 1, 0, b)
                    else:
                        m[:, 3] = 0
                        m[fa == 0, 3] = np.cumsum(np.asarray(
                            b, dtype=np.int64))
                    m[:, 4] = (c if type(c) is int
                               else np.cumsum(np.asarray(
                                   c, dtype=np.int64)))
                    for idx in np.split(order, cuts):
                        rank = int(ra[idx[0]])
                        if rsel is not None and rank not in rsel:
                            continue
                        if rec_on and split_count:
                            n_ops += len(idx)
                        segs = get_segs(rank)
                        if segs is None:
                            segs = pending[rank] = []
                        segs.append([2, 0, 0, 0,
                                     m[idx].ravel().tolist()])
                    continue
                flags = (repeat(p, n) if type(p) is int
                         else decode_flags(p, n))
                ranks = repeat(r, n) if type(r) is int else accumulate(r)
                srcs = repeat(s, n) if type(s) is int else accumulate(s)
                tags = repeat(g, n) if type(g) is int else accumulate(g)
                comms = repeat(c, n) if type(c) is int else accumulate(c)
                nbs = (repeat(b) if type(b) is int
                       else iter(list(accumulate(b))))
                for p_, r_, s_, g_, c_ in zip(flags, ranks, srcs, tags,
                                              comms):
                    nb_ = 0 if p_ else next(nbs)
                    if rsel is not None and r_ not in rsel:
                        continue
                    if rec_on and split_count:
                        n_ops += 1
                    segs = get_segs(r_)
                    if segs is None:
                        segs = pending[r_] = [[p_, g_, c_, nb_, [s_]]]
                        continue
                    last = segs[-1]
                    if (last[0] == p_ and last[1] == g_
                            and last[2] == c_ and last[3] == nb_):
                        last[4].append(s_)
                    else:
                        segs.append([p_, g_, c_, nb_, [s_]])
                continue
            tw = rec.get("t_wall")
            if tw is not None:
                if wall_lo is None:
                    wall_lo = tw
                wall_hi = tw
            if kind == REC_POST or kind == REC_ARRIVE:
                r = rec["rank"]
                if rsel is not None and r not in rsel:
                    continue
                if rec_on:
                    n_ops += 1
                p_ = 1 if kind == REC_POST else 0
                g_ = rec["tag"]
                c_ = rec.get("comm", 0)
                nb_ = 0 if p_ else rec.get("nb", 0)
                s_ = rec["src"]
                segs = get_segs(r)
                if segs is None:
                    pending[r] = [[p_, g_, c_, nb_, [s_]]]
                else:
                    last = segs[-1]
                    if (last[0] == p_ and last[1] == g_
                            and last[2] == c_ and last[3] == nb_):
                        last[4].append(s_)
                    else:
                        segs.append([p_, g_, c_, nb_, [s_]])
            elif kind == REC_PHASE:
                flush_phase()
                pidx += 1
                current = PhaseStats(
                    index=pidx, label=rec["label"], op=rec["op"],
                    attrs={k: v for k, v in rec.items()
                           if k not in ("t", "op", "label")})
                if prange is not None:
                    rec_on = lo <= pidx < hi
                    registry.enabled = rec_on
                    if pidx >= hi:
                        # range fully recorded and it does not extend to
                        # the stream tail: nothing left for this shard
                        stopped = True
                        break
            elif kind == REC_PROGRESS:
                # under phase partitioning, aux records (progress lanes,
                # final snapshot) belong to the shard whose range covers
                # them; the merge concatenates shards in phase order
                if rec_on:
                    pe_records.append(rec)
            elif kind == REC_PE_CHUNK:
                if not rec_on:
                    continue
                expanded = decode_pe_chunk(rec)
                pe_records.extend(expanded)
                for pe in expanded:
                    tw = pe.get("t_wall")
                    if tw is not None:
                        if wall_lo is None:
                            wall_lo = tw
                        wall_hi = tw
            elif kind == REC_SNAPSHOT:
                if rec_on:
                    raw_snap = rec
        if not stopped:
            flush_phase()

        progress_mode = self.progress_mode
        if pe_records:
            progress_mode = progress_mode or "incoming"

        return ReplayResult(
            mode=mode, progress_mode=progress_mode, header=header,
            matches=[], divergences=[], phases=phases,
            registry=registry, pe_records=pe_records,
            raw_snap=raw_snap, n_ops=n_ops, phase_ns=self.phase_ns)


def replay(source: Union[str, TraceReader, Tuple[Dict, Sequence[Dict]]],
           mode: Optional[str] = None,
           progress_mode: Optional[str] = None,
           check_matches: bool = True,
           strict: bool = True) -> ReplayResult:
    """One-call replay: ``replay(path, mode="linear")``;
    ``check_matches=False`` streams batched (fast, no per-op outcome
    verification); ``strict=False`` skips corrupt source lines (see
    ``ReplayResult.skipped_records``)."""
    return Replayer(mode=mode, progress_mode=progress_mode,
                    check_matches=check_matches, strict=strict
                    ).run(source)
