"""Deterministic what-if replay of recorded communication traces.

A recorded trace is an ordered stream of matching-engine operations
(post/arrive with envelopes), phase markers and progress-engine lane
events. Replay re-drives that exact stream through a *fresh* set of
engines in any mode (``binned``/``fifo``, ``linear``, ``leaky_umq``) —
no JAX, no re-execution of the workload — and produces the same
artifacts a live run produces:

  * per-rank, per-phase counter statistics (one registry lane per rank),
  * ``core.counters`` snapshot Events (category ``"counter"``) at every
    phase boundary, so ``long_traversal`` / ``umq_flood`` and the rest of
    :mod:`repro.core.analyses` run on replayed data unchanged,
  * modeled progress-engine lock Events under either queue discipline
    (the §4 shared-queue defect vs the incoming-queue fix), so
    ``contention`` runs on replayed data too.

Because the seeded defects change *cost*, never *matching* (the
engine-mode equivalence property ``tests/test_match.py`` pins down),
replaying under a different mode answers "what would this exact run have
cost on that engine?" — and replaying under the same mode reproduces the
recorded match order exactly (``divergences`` stays empty).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from ..core.counters import CounterRegistry, CounterStat, counter_stats
from ..core.events import Event
from ..match import MatchEngine, canonical_mode
from .io import read_trace
from .schema import (REC_ARRIVE, REC_PHASE, REC_POST, REC_PROGRESS,
                     REC_SNAPSHOT)

# mirrors repro.comm.progress.LOCK_REGION without importing the comm layer
# (which would pull in JAX — replay stays JAX-free)
LOCK_REGION = "BlockingProgress lock"

# synthetic spacing between phase snapshots on the replay timeline
PHASE_NS = 1_000_000


@dataclasses.dataclass
class PhaseStats:
    """Counter deltas attributed to one recorded phase, per rank.

    ``wall_ns`` is the measured live wall-clock span of the phase's
    recorded ops (schema v2 ``t_wall`` stamps); ``None`` for v1 traces
    or deterministic-mode recordings."""

    index: int
    label: str
    op: str
    attrs: Dict = dataclasses.field(default_factory=dict)
    stats: Dict[int, Dict[str, CounterStat]] = dataclasses.field(
        default_factory=dict)
    wall_ns: Optional[int] = None

    def metric(self, rank: int, name: str) -> Optional[CounterStat]:
        return self.stats.get(rank, {}).get(name)


@dataclasses.dataclass
class ReplayResult:
    mode: str
    progress_mode: Optional[str]
    header: Dict
    matches: List[Tuple[int, str, int, Optional[int]]]
    divergences: List[Dict]
    phases: List[PhaseStats]
    events: List[Event]
    registry: CounterRegistry
    recorded_stats: Optional[Dict[int, Dict[str, CounterStat]]] = None

    def totals(self) -> Dict[str, CounterStat]:
        """Replayed counter statistics aggregated across ranks."""
        return counter_stats(self.events)

    def measured_wall_s(self) -> Optional[float]:
        """Total measured live wall time across phases (v2 ``t_wall``
        stamps), or ``None`` when the trace carries no timing (v1, or
        recorded in deterministic mode)."""
        spans = [p.wall_ns for p in self.phases if p.wall_ns is not None]
        return sum(spans) / 1e9 if spans else None

    def dilation(self, baseline: "ReplayResult") -> Optional[float]:
        """Measured wall-time dilation of this trace's live run relative
        to ``baseline``'s (e.g. a defective recording vs a healthy one).
        ``None`` unless both traces carry ``t_wall`` timing."""
        a, b = baseline.measured_wall_s(), self.measured_wall_s()
        if a is None or b is None or a <= 0:
            return None
        return b / a

    def totals_by_rank(self) -> Dict[int, Dict[str, CounterStat]]:
        per: Dict[int, List[Event]] = {}
        for ev in self.events:
            per.setdefault(ev.pid, []).append(ev)
        return {pid: counter_stats(evs) for pid, evs in per.items()}


def _parse_snap(rec: Dict) -> Dict[int, Dict[str, CounterStat]]:
    out: Dict[int, Dict[str, CounterStat]] = {}
    for pid, per in rec["stats"].items():
        out[int(pid)] = {name: CounterStat.from_attrs(attrs)
                         for name, attrs in per.items()}
    return out


def replay_progress(pe_records: Sequence[Dict], mode: str = "incoming",
                    pid: int = 0, swap_ns: int = 1_000) -> List[Event]:
    """Re-model recorded progress-engine lane events under a queue
    discipline (deterministic queueing model over the recorded submit
    times and processing quanta):

      * ``"shared"`` — one queue: the progress thread holds the lock for
        whole processing quanta, so a submit landing inside a busy span
        waits for the span to end. Lock-hold Events overlap across
        threads, which ``core.analyses.contention`` flags — the paper's
        Fig. 8, reconstructed offline.
      * ``"incoming"`` — second queue: the lock is held only for an O(1)
        append/swap; lock Events never overlap and the timeline is clean.

    tid 0 is the user thread, tid 1 the progress thread (the same lane
    convention as the live timeline)."""
    assert mode in ("shared", "incoming")
    # concurrent submitters can win the trace-writer lock out of enqueue
    # order; ts is captured pre-lock, so sorting restores arrival order
    # before submits are paired positionally with FIFO-processed quanta
    submits = sorted((r for r in pe_records if r.get("ev") == "submit"),
                     key=lambda r: r["ts"])
    procs = sorted((r for r in pe_records if r.get("ev") == "proc"),
                   key=lambda r: r["ts"])
    if not submits or not procs:
        return []
    base = min(r["ts"] for r in submits + procs)
    events: List[Event] = []

    def lock_event(tid: int, t0: int, t1: int) -> Event:
        return Event(name=LOCK_REGION, path=("replay", LOCK_REGION),
                     category="runtime", t_start=t0, t_end=t1, pid=pid,
                     tid=tid, attrs={"lock": "request_queue",
                                     "replayed": mode})

    if mode == "shared":
        # progress thread drains back-to-back, holding the lock for whole
        # processing quanta; request i completes at C_i
        spans: List[Tuple[int, int]] = []
        completions: List[int] = []
        frontier: Optional[int] = None
        for sub, proc in zip(submits, procs):
            s = sub["ts"] - base
            start = s if frontier is None or frontier <= s else frontier
            end = start + int(proc.get("dur", 0))
            events.append(Event(
                name="progress/process", path=("replay", "progress",
                                               "process"),
                category="runtime", t_start=start, t_end=end, pid=pid,
                tid=1))
            spans.append((start, end))
            completions.append(end)
            frontier = end
        merged: List[Tuple[int, int]] = []
        for a, b in spans:
            if merged and a <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], b))
            else:
                merged.append((a, b))
        for a, b in merged:
            events.append(lock_event(1, a, b))
        # submit j blocks behind the processing of every *earlier*
        # request (the paper's Fig. 10: Isend latency grows with the
        # number of pending requests). Submits without a matching proc
        # record (engine shut down with requests still queued) block
        # behind the last *known* completion.
        for j, sub in enumerate(submits):
            s = sub["ts"] - base
            release = s + swap_ns
            if j > 0 and completions:
                release = max(release,
                              completions[min(j, len(completions)) - 1])
            events.append(lock_event(0, s, release))
    else:
        frontier = 0
        for sub, proc in zip(submits, procs):
            s = sub["ts"] - base
            events.append(lock_event(0, s, s + swap_ns))
            # instant swap on the progress thread: zero-width hold, no
            # cross-thread overlap possible
            events.append(lock_event(1, s + swap_ns, s + swap_ns))
            start = max(s + swap_ns, frontier)
            end = start + int(proc.get("dur", 0))
            events.append(Event(
                name="progress/process", path=("replay", "progress",
                                               "process"),
                category="runtime", t_start=start, t_end=end, pid=pid,
                tid=1))
            frontier = end
    events.sort(key=lambda e: (e.t_start, e.t_end))
    return events


class Replayer:
    """Re-drive a recorded trace through an alternate engine config.

    ``mode`` overrides the engine mode (default: the recorded one);
    ``progress_mode`` picks the queue discipline for progress-engine lane
    events (default: leave them out unless the trace has any, then replay
    as ``"incoming"``)."""

    def __init__(self, mode: Optional[str] = None,
                 progress_mode: Optional[str] = None,
                 phase_ns: int = PHASE_NS):
        self.mode = mode
        self.progress_mode = progress_mode
        self.phase_ns = phase_ns

    def run(self, source: Union[str, Tuple[Dict, List[Dict]]]
            ) -> ReplayResult:
        if isinstance(source, (tuple, list)):
            header, records = source
        else:
            header, records = read_trace(source)
        mode = canonical_mode(self.mode or header.get("mode", "binned"))

        registry = CounterRegistry()
        engines: Dict[int, MatchEngine] = {}

        def engine(rank: int) -> MatchEngine:
            eng = engines.get(rank)
            if eng is None:
                eng = engines[rank] = MatchEngine(
                    rank=rank, mode=mode, registry=registry.lane(rank))
            return eng

        phases: List[PhaseStats] = []
        events: List[Event] = []
        matches: List[Tuple[int, str, int, Optional[int]]] = []
        divergences: List[Dict] = []
        pe_records: List[Dict] = []
        recorded_stats: Optional[Dict[int, Dict[str, CounterStat]]] = None
        current = PhaseStats(index=0, label="prologue", op="phase")
        wall: List[int] = []          # t_wall stamps seen in current phase

        def flush_phase() -> None:
            t = (len(phases) + 1) * self.phase_ns
            evs = registry.snapshot_events(t_ns=t)
            per: Dict[int, List[Event]] = {}
            for ev in evs:
                ev.attrs["phase"] = current.label
                ev.attrs["phase_index"] = current.index
                per.setdefault(ev.pid, []).append(ev)
            current.stats = {pidx: counter_stats(group)
                             for pidx, group in per.items()}
            if wall:
                current.wall_ns = max(wall) - min(wall)
                del wall[:]
            phases.append(current)
            events.extend(evs)

        for rec in records:
            kind = rec["t"]
            if "t_wall" in rec:
                wall.append(rec["t_wall"])
            if kind == REC_PHASE:
                flush_phase()
                current = PhaseStats(
                    index=len(phases), label=rec["label"], op=rec["op"],
                    attrs={k: v for k, v in rec.items()
                           if k not in ("t", "op", "label")})
            elif kind == REC_POST:
                r = engine(rec["rank"]).post_recv(
                    src=rec["src"], tag=rec["tag"], comm=rec.get("comm", 0))
                got = r.message.seq if r.message is not None else None
                matches.append((rec["rank"], "post", r.seq, got))
                if "hit" in rec and rec["hit"] != got:
                    divergences.append(
                        {"rec": rec, "replayed": got, "mode": mode})
            elif kind == REC_ARRIVE:
                r = engine(rec["rank"]).arrive(
                    src=rec["src"], tag=rec["tag"],
                    comm=rec.get("comm", 0), nbytes=rec.get("nb", 0))
                got = r.seq if r is not None else None
                matches.append((rec["rank"], "arr", rec["seq"], got))
                if "match" in rec and rec["match"] != got:
                    divergences.append(
                        {"rec": rec, "replayed": got, "mode": mode})
            elif kind == REC_PROGRESS:
                pe_records.append(rec)
            elif kind == REC_SNAPSHOT:
                recorded_stats = _parse_snap(rec)
        flush_phase()

        progress_mode = self.progress_mode
        if pe_records:
            progress_mode = progress_mode or "incoming"
            events.extend(replay_progress(pe_records, progress_mode))

        return ReplayResult(
            mode=mode, progress_mode=progress_mode, header=header,
            matches=matches, divergences=divergences, phases=phases,
            events=events, registry=registry,
            recorded_stats=recorded_stats)


def replay(source: Union[str, Tuple[Dict, List[Dict]]],
           mode: Optional[str] = None,
           progress_mode: Optional[str] = None) -> ReplayResult:
    """One-call replay: ``replay(path, mode="linear")``."""
    return Replayer(mode=mode, progress_mode=progress_mode).run(source)
