"""Production meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices for these shapes.
"""
from __future__ import annotations

from ..core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 1):
    """Best-effort (data, model) mesh over whatever devices exist — used by
    CPU tests (1..8 host devices) and the elastic restart path."""
    assert n_devices % model_parallel == 0, (n_devices, model_parallel)
    return make_mesh((n_devices // model_parallel, model_parallel),
                     ("data", "model"))
