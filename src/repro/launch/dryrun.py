import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count at
# first init. The dry-run (and only the dry-run) needs 512 placeholders.

import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs.archs import get_config                     # noqa: E402
from ..configs.base import SHAPES, shapes_for              # noqa: E402
from ..core import hlo, hlo_cost                           # noqa: E402
from ..core.device_timeline import (                       # noqa: E402
    extract_schedule, serialization_report)
from ..core.roofline import HW, Roofline                   # noqa: E402
from ..models import model as M                            # noqa: E402
from ..optim import adamw                                  # noqa: E402
from ..sharding import rules as R                          # noqa: E402
from ..train.step import (                                 # noqa: E402
    make_decode_step, make_prefill_step, make_train_step)
from . import flops as F                                   # noqa: E402
from .mesh import make_production_mesh                     # noqa: E402
from .specs import input_specs                             # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def shardings_for(cfg, shape, mesh, rules, specs):
    param_sh = R.tree_shardings(M.param_axes(cfg), mesh, rules,
                                M.param_shapes(cfg))
    if shape.kind == "train":
        opt_sh = {
            "m": param_sh, "v": param_sh,
            "step": NamedSharding(mesh, P()),
        }
        batch_sh = R.batch_shardings(specs["batch"], mesh, rules)
        in_sh = (param_sh, opt_sh, batch_sh)
        out_sh = (param_sh, opt_sh, NamedSharding(mesh, P()))
        return in_sh, out_sh
    if shape.kind == "prefill":
        batch_sh = R.batch_shardings(specs["batch"], mesh, rules)
        cache_sh = R.cache_shardings(
            M.init_cache_shapes(cfg, shape.global_batch, shape.seq_len),
            mesh, rules)
        logits_sh = NamedSharding(mesh, R.pspec(("batch", None, "vocab"), rules))
        return (param_sh, batch_sh), (logits_sh, cache_sh)
    # decode
    cache_sh = R.cache_shardings(specs["caches"], mesh, rules)
    batch_sh = R.batch_shardings(specs["batch"], mesh, rules)
    pos_sh = NamedSharding(mesh, P())
    logits_sh = NamedSharding(mesh, R.pspec(("batch", None, "vocab"), rules))
    tok_sh = NamedSharding(mesh, R.pspec(("batch", None), rules))
    in_sh = (param_sh, cache_sh, batch_sh, pos_sh)
    out_sh = (logits_sh, tok_sh, cache_sh)
    return in_sh, out_sh


def step_and_args(cfg, shape, specs, microbatches: int = 1):
    if shape.kind == "train":
        step = make_train_step(cfg, adamw.AdamWConfig(),
                               microbatches=microbatches)
        args = (specs["params"], specs["opt_state"], specs["batch"])
        donate = (0, 1)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        args = (specs["params"], specs["batch"])
        donate = ()
    else:
        step = make_decode_step(cfg)
        args = (specs["params"], specs["caches"], specs["batch"],
                specs["pos"])
        donate = (1,)
    return step, args, donate


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, verbose: bool = True,
             microbatches: int = 1, fused_accounting: bool = False,
             tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rules = R.make_rules(mesh, shape)
    specs = input_specs(cfg, shape)
    in_sh, out_sh = shardings_for(cfg, shape, mesh, rules, specs)
    step, args, donate = step_and_args(cfg, shape, specs,
                                       microbatches=microbatches)

    t0 = time.time()
    with R.sharding_context(mesh, rules):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    mc = hlo_cost.module_cost(
        txt, vmem_fused_tag="vmem_fused" if fused_accounting else None)
    stats = hlo.collective_stats(txt)            # unscaled (per occurrence)
    model_fl = F.model_flops(cfg, shape)
    roof = Roofline(
        flops=mc.flops,
        hbm_bytes=mc.bytes_accessed,
        wire_bytes=mc.collective_wire_bytes,
        n_chips=n_chips,
        model_flops=model_fl,
    )
    try:
        sched = extract_schedule(txt)
        ser = serialization_report(sched)
        ser_d = {
            "t_compute": ser.t_compute,
            "t_collective_total": ser.t_collective_total,
            "t_collective_exposed": ser.t_collective_exposed,
            "exposed_fraction": ser.exposed_fraction,
            "n_collectives": ser.n_collectives,
            "n_overlapped": ser.n_overlapped,
        }
    except Exception as e:                        # pragma: no cover
        ser_d = {"error": str(e)}

    per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "ok": True,
        "microbatches": microbatches,
        "fused_accounting": fused_accounting,
        "tag": tag,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_total": per_dev_bytes,
            "fits_hbm": per_dev_bytes <= HW["hbm_gb"] * 1e9,
        },
        "xla_cost_analysis": {
            "flops": ca.get("flops"), "bytes_accessed": ca.get("bytes accessed"),
            "note": "while bodies counted once by XLA; see walker_*",
        },
        "walker": {
            "flops_per_device": mc.flops,
            "bytes_per_device": mc.bytes_accessed,
            "collective_operand_bytes": mc.collective_operand_bytes,
            "collective_wire_bytes": mc.collective_wire_bytes,
            "collective_count": mc.collective_count,
            "collectives_by_opcode": mc.collectives_by_opcode,
            "top_collectives": mc.top_collectives(12),
            "trip_counts": mc.trip_counts[:32],
        },
        "collectives_unscaled": {
            "count": stats.count,
            "operand_bytes": stats.total_operand_bytes,
            "wire_bytes": stats.total_wire_bytes,
            "by_opcode": stats.by_opcode,
        },
        "model_flops": model_fl,
        "roofline": roof.to_dict(),
        "schedule": ser_d,
    }
    if verbose:
        print(f"== {arch} x {shape_name} on {result['mesh']} "
              f"({n_chips} chips) ==")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory/device: {per_dev_bytes/1e9:.2f} GB "
              f"(fits 16GB: {result['memory']['fits_hbm']})")
        print(f"  {compiled.memory_analysis()}")
        print(f"  cost_analysis flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  walker flops/dev={mc.flops:.3e} bytes/dev="
              f"{mc.bytes_accessed:.3e} wire/dev="
              f"{mc.collective_wire_bytes:.3e}")
        print("  roofline: " + roof.summary())
        print(f"  {json.dumps(ser_d)}")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fname = f"{arch}__{shape_name}__{result['mesh']}{suffix}.json"
        with open(os.path.join(RESULTS_DIR, fname), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every cell in subprocesses (fault-isolated)")
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fused-accounting", action="store_true",
                    help="charge vmem_fused-tagged kernel interiors zero "
                         "HBM bytes (the Pallas-kernel-equivalent path)")
    ap.add_argument("--tag", default="",
                    help="suffix for the result JSON (e.g. 'opt')")
    args = ap.parse_args()

    if args.all:
        import subprocess
        from ..configs.archs import ARCHS

        failures = []
        for arch in ARCHS:
            cfg = get_config(arch)
            for shape_name in shapes_for(cfg):
                for mp in (False, True):
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape_name]
                    if mp:
                        cmd.append("--multi-pod")
                    print(">>", " ".join(cmd), flush=True)
                    rc = subprocess.call(cmd)
                    if rc != 0:
                        failures.append((arch, shape_name, mp))
        print(f"dryrun --all finished; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    try:
        run_cell(args.arch, args.shape, args.multi_pod,
                 save=not args.no_save, microbatches=args.microbatches,
                 fused_accounting=args.fused_accounting, tag=args.tag)
    except Exception:
        traceback.print_exc()
        # record the failure for the driver
        os.makedirs(RESULTS_DIR, exist_ok=True)
        mesh_name = "2x16x16" if args.multi_pod else "16x16"
        fname = f"{args.arch}__{args.shape}__{mesh_name}.json"
        with open(os.path.join(RESULTS_DIR, fname), "w") as f:
            json.dump({"arch": args.arch, "shape": args.shape,
                       "mesh": mesh_name, "ok": False,
                       "error": traceback.format_exc()[-2000:]}, f)
        sys.exit(1)


if __name__ == "__main__":
    main()
