"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --preset smoke \
        --steps 20 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Wires every substrate together: config -> mesh -> sharded params ->
profiled train loop -> async checkpoints -> straggler detector -> trace
export. On CPU it runs the reduced presets; on a real TPU fleet the same
driver takes the full configs (the dry-run proves those lower+compile).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..checkpoint.straggler import StragglerDetector
from ..configs.archs import get_config
from ..core import regions, timeline
from ..core.collector import global_collector, reset_global_collector
from ..core.graphframe import GraphFrame
from ..data.pipeline import DataConfig, SyntheticTokens
from ..models import model as M
from ..optim import adamw
from ..sharding import rules as R
from ..train.step import make_train_step
from .mesh import make_mesh_for


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "constant"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--trace-out", default=None)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. ~100M-param e2e run)")
    ap.add_argument("--layers", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, args.preset)
    if args.d_model:
        cfg = dataclasses.replace(
            cfg, d_model=args.d_model,
            d_ff=args.d_model * 4 if cfg.d_ff else 0,
            n_heads=max(4, args.d_model // 64),
            n_kv_heads=max(4, args.d_model // 64), d_head=64)
    if args.layers:
        plen = len(cfg.pattern)
        cfg = dataclasses.replace(
            cfg, n_layers=max(plen, args.layers // plen * plen))
    # MiniCPM trains with WSD per its paper
    schedule = "wsd" if cfg.name.startswith("minicpm") else args.schedule

    mesh = make_mesh_for(len(jax.devices()), args.model_parallel)
    rules = R.make_rules(mesh)
    print(f"arch={cfg.name} preset={args.preset} devices={mesh.devices.size} "
          f"mesh={dict(mesh.shape)}")
    print(f"params: {M.param_count(cfg):,} "
          f"(active {M.active_param_count(cfg):,})")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, schedule=schedule,
                                warmup_steps=max(2, args.steps // 10),
                                total_steps=args.steps)
    data = SyntheticTokens(cfg, DataConfig(batch=args.batch, seq_len=args.seq))

    ckpt = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
    start_step = 0
    with R.sharding_context(mesh, rules):
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = adamw.init_state(params)
        if ckpt and args.resume:
            restored = ckpt.restore()
            if restored:
                start_step, host_state, _ = restored
                from ..checkpoint.elastic import reshard_state
                st = reshard_state(cfg, host_state, mesh)
                params, opt_state = st["params"], st["opt_state"]
                print(f"resumed from step {start_step}")

        step_fn = jax.jit(make_train_step(cfg, opt_cfg),
                          donate_argnums=(0, 1))
        detector = StragglerDetector()
        reset_global_collector()
        losses = []
        for step in range(start_step, args.steps):
            with regions.annotate("train/step", category="app", step=step) :
                with regions.annotate("train/data", category="data"):
                    batch = {k: jnp.asarray(v)
                             for k, v in data.batch_at(step).items()}
                t0 = time.perf_counter()
                with regions.annotate("train/compute", category="api"):
                    params, opt_state, metrics = step_fn(
                        params, opt_state, batch)
                    loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                detector.record(rank=0, step=step, duration_s=dt)
                losses.append(loss)
                if ckpt and (step + 1) % args.ckpt_every == 0:
                    with regions.annotate("train/checkpoint",
                                          category="runtime"):
                        ckpt.save(step + 1, {
                            "params": params, "opt_state": opt_state})
            if step < start_step + 3 or (step + 1) % 10 == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt*1e3:.0f} ms)")
        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt_state": opt_state})
            ckpt.wait()
            ckpt.close()

        events = global_collector().drain()
        gf = GraphFrame.from_events(events)
        print("\nprofile (inclusive seconds):")
        print(gf.tree(metric="sum", fmt="{:.3f}", max_depth=2))
        if args.trace_out:
            timeline.save_trace(timeline.to_chrome_trace(events),
                                args.trace_out)
            print(f"chrome trace -> {args.trace_out}")
        if detector.flagged:
            print("straggler findings:",
                  *[str(f) for f in detector.flagged], sep="\n  ")
        print(f"\nfinal loss {losses[-1]:.4f} (first {losses[0]:.4f})")
        return losses


if __name__ == "__main__":
    main()
