"""MODEL_FLOPS: the useful-math floor for each (arch x shape) cell.

6*N*D for training (2*N*D forward, x3 with backward), with N = *active*
matmul params (MoE counts top-k + shared experts only, embedding-table
lookups excluded), plus the sequence-mixing terms that are not param
matmuls: causal attention at T^2/2 (the optimal causal schedule),
sliding-window at T*W, mLSTM chunk products, mamba scan elementwise ops.
"""
from __future__ import annotations

from ..configs.base import ModelConfig, ShapeConfig
from ..models.model import active_param_count


def matmul_param_count(cfg: ModelConfig) -> int:
    """Active params engaged in per-token matmuls: no embedding-table
    gather, no padded vocab tail of the lm_head."""
    n = active_param_count(cfg)
    Vp = cfg.padded_vocab_size
    if cfg.input_mode != "frames":
        n -= Vp * cfg.d_model                      # embedding gather
    n -= cfg.n_codebooks * (Vp - cfg.vocab_size) * cfg.d_model
    return n


def _attn_layer_counts(cfg: ModelConfig):
    full, windowed, cross = 0, 0, 0
    for s in cfg.pattern:
        if s.mixer == "attn":
            if s.window is None:
                full += 1
            else:
                windowed += 1
        if s.cross_attn:
            cross += 1
    g = cfg.n_groups
    return full * g, windowed * g, cross * g


def mixer_flops_token(cfg: ModelConfig, ctx: int, window_ctx: int) -> float:
    """Sequence-mixing flops for ONE token attending over `ctx` history."""
    H, D = cfg.n_heads, cfg.head_dim
    n_full, n_win, n_cross = _attn_layer_counts(cfg)
    f = 0.0
    f += n_full * 4.0 * H * D * ctx
    f += n_win * 4.0 * H * D * window_ctx
    f += n_cross * 4.0 * H * D * max(cfg.encoder_len, 0)
    # state-space / recurrent mixers, per layer
    f_state = 0.0
    for s in cfg.pattern:
        if s.mixer == "mamba" and cfg.mamba:
            dI = cfg.mamba.expand * cfg.d_model
            f_state += 10.0 * dI * cfg.mamba.d_state
        if s.mixer == "mlstm" and cfg.xlstm:
            dI = int(cfg.d_model * cfg.xlstm.proj_factor_mlstm)
            Dh = dI // cfg.n_heads
            q = cfg.xlstm.chunk
            f_state += 4.0 * cfg.n_heads * Dh * min(q, max(ctx, 1))
            f_state += 4.0 * dI * Dh                   # inter-chunk state read
        if s.mixer == "slstm":
            pass                                       # r_gates already in params
    f += f_state * cfg.n_groups
    return f


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global useful flops for one step of this cell."""
    Np = matmul_param_count(cfg)
    B = shape.global_batch
    if shape.kind == "train":
        T = shape.seq_len
        tokens = B * T
        # mean causal context = T/2; windowed context = min(W, T/2-ish) ~ W
        mix = sum(
            mixer_flops_token(cfg, ctx=T // 2, window_ctx=1024)
            for _ in range(1)
        ) * tokens
        return 6.0 * Np * tokens + 3.0 * mix
    if shape.kind == "prefill":
        T = shape.seq_len
        tokens = B * T
        mix = mixer_flops_token(cfg, ctx=T // 2, window_ctx=1024) * tokens
        return 2.0 * Np * tokens + mix
    # decode: one token against a seq_len-deep cache
    ctx = shape.seq_len
    mix = mixer_flops_token(cfg, ctx=ctx, window_ctx=1024) * B
    return 2.0 * Np * B + mix
