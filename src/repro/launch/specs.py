"""ShapeDtypeStruct stand-ins for every model input of every cell —
weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import model as M

I32 = jnp.int32


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B = shape.global_batch
    T = 1 if shape.is_decode else shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    out: Dict[str, Any] = {}
    if cfg.input_mode == "frames":
        out["frames"] = jax.ShapeDtypeStruct((B, T, cfg.d_model), dt)
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((B, T, cfg.n_codebooks), I32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, T), I32)
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((B, T), I32)
    if cfg.input_mode == "tokens+image" and not shape.is_decode:
        out["encoder_embeddings"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_len, cfg.d_model), dt)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """All step inputs for one (arch x shape) cell.

    train:   {params, opt_state, batch}
    prefill: {params, batch}
    decode:  {params, caches, batch, pos}
    """
    if shape.kind == "train":
        params = M.param_shapes(cfg, jnp.float32)
        opt = {
            "m": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params),
            "v": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params),
            "step": jax.ShapeDtypeStruct((), I32),
        }
        return {"params": params, "opt_state": opt,
                "batch": batch_specs(cfg, shape)}
    params = M.param_shapes(cfg, jnp.dtype(cfg.dtype))   # serving weights
    if shape.kind == "prefill":
        return {"params": params, "batch": batch_specs(cfg, shape)}
    caches = M.init_cache_shapes(cfg, shape.global_batch, shape.seq_len)
    return {
        "params": params,
        "caches": caches,
        "batch": batch_specs(cfg, shape),
        "pos": jax.ShapeDtypeStruct((), I32),
    }
