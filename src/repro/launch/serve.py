"""Batched serving driver: prefill + greedy decode with profiling.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --preset smoke \
        --batch 4 --prompt-len 32 --gen 16

Serves a batch of synthetic prompts through the real prefill/decode steps
(same code the dry-run lowers at 512 chips), with per-phase profiling
regions and a tokens/s report.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs.archs import get_config
from ..core import regions
from ..core.collector import global_collector, reset_global_collector
from ..core.graphframe import GraphFrame
from ..models import model as M
from ..train.step import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--telemetry", action="store_true",
                    help="serve live counter/region telemetry over "
                         "HTTP/SSE while prefill/decode run")
    ap.add_argument("--telemetry-port", type=int, default=0,
                    help="bind port for --telemetry (default: ephemeral)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, args.preset)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{cfg.name}: serving demo expects token input")
    B, P, G = args.batch, args.prompt_len, args.gen
    total = P + G
    params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=cfg.dtype)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    collector = reset_global_collector()
    bridge = server = None
    if args.telemetry:
        from ..core.counters import global_registry
        from ..telemetry import TelemetryBridge, TelemetryServer
        bridge = TelemetryBridge(session=f"serve[{cfg.name}]")
        bridge.watch(global_registry(), name="counters")
        bridge.watch_events(collector, name="regions")
        server = TelemetryServer(bridge, port=args.telemetry_port).start()
        bridge.start()
        print(f"telemetry: {server.url}/metrics | /stream | /findings")

    with regions.annotate("serve/prefill", category="api"):
        logits, caches = prefill(params, {"tokens": prompts})
        jax.block_until_ready(logits)
    # grow caches to generation capacity
    def grow(path, arr):
        nm = path[-1].key
        if nm in ("k", "v") and arr.ndim == 5 and arr.shape[2] == P:
            pad = jnp.zeros((arr.shape[0], arr.shape[1], total - P)
                            + arr.shape[3:], arr.dtype)
            return jnp.concatenate([arr, pad], axis=2)
        if nm == "pos" and arr.ndim == 2 and arr.shape[1] == P:
            return jnp.concatenate(
                [arr, jnp.full((arr.shape[0], total - P), -1, jnp.int32)], 1)
        return arr

    caches = jax.tree_util.tree_map_with_path(grow, caches)
    token = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [token]
    t0 = time.perf_counter()
    for t in range(P, total):
        with regions.annotate("serve/decode_step", category="api", pos=t):
            logits, next_tok, caches = decode(
                params, caches, {"tokens": token}, jnp.int32(t))
            token = next_tok[:, 0][:, None]
            out_tokens.append(token)
    jax.block_until_ready(token)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"{cfg.name}: prefill {B}x{P}, generated {B}x{G} greedy tokens")
    print(f"decode throughput: {B * G / dt:.1f} tok/s "
          f"({dt / G * 1e3:.1f} ms/step)")
    print("sample:", gen[0, :16].tolist())
    if bridge is not None:
        bridge.stop()
        print(f"telemetry: {bridge.polls} polls, {bridge.deltas_total} "
              f"deltas, {len(bridge.findings_json())} live findings")
        server.stop()
        bridge.close()
    gf = GraphFrame.from_events(global_collector().drain())
    print(gf.tree(metric="sum", fmt="{:.3f}", max_depth=1))
    return gen


if __name__ == "__main__":
    main()
