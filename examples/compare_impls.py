"""Comparison-based profiling (paper method 1), end to end.

    PYTHONPATH=src:. python examples/compare_impls.py

Runs the COMB-analog halo app under the vendor backend (xla_auto) and two
builds of the explicit backend (pre-fix one-queue + host defect; post-fix
two-queue), aggregates N runs per implementation into GraphFrames,
divides the trees, and prints the paper-Fig-2/3-style ratio trees plus
the hotspot list that tells you where to optimize next.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.figures import fig2_fig3_comparison_trees, fig5_completion_times


def main():
    print("Method 1: comparison-based profiling")
    print("baseline = xla_auto (vendor black box / 'Spectrum' analog)\n")
    fig2_fig3_comparison_trees()
    print()
    fig5_completion_times()


if __name__ == "__main__":
    main()
