"""Quickstart: profile a small LM training run with the core library.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the 60-second path: annotate regions -> train a few steps ->
print the Hatchet-style tree -> export a Chromium trace you can open in
chrome://tracing or Perfetto (the paper's viewers).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.archs import get_config
from repro.core import annotate, regions, timeline
from repro.core.collector import global_collector, reset_global_collector
from repro.core.graphframe import GraphFrame
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import model as M
from repro.optim import adamw
from repro.train.step import make_train_step


def main():
    cfg = get_config("yi-6b", "smoke")
    data = SyntheticTokens(cfg, DataConfig(batch=4, seq_len=128))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    step = jax.jit(make_train_step(cfg, adamw.AdamWConfig(total_steps=8)),
                   donate_argnums=(0, 1))

    reset_global_collector()
    for i in range(8):
        with annotate("train/step", step=i):
            with annotate("train/data", category="data"):
                batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            with annotate("train/compute", category="api") :
                params, opt, metrics = step(params, opt, batch)
                jax.block_until_ready(metrics["loss"])
        print(f"step {i}: loss {float(metrics['loss']):.4f}")

    events = global_collector().drain()
    gf = GraphFrame.from_events(events)
    print("\nregion tree (mean seconds per occurrence):")
    print(gf.tree(metric="mean", fmt="{:.4f}"))
    out = "/tmp/quickstart_trace.json"
    timeline.save_trace(timeline.to_chrome_trace(events), out)
    print(f"\nchrome trace written to {out} (open in chrome://tracing)")


if __name__ == "__main__":
    main()
