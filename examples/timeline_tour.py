"""Timeline profiling (paper method 2), end to end.

    PYTHONPATH=src:. python examples/timeline_tour.py

1. Runs the halo app with the one-queue progress engine and captures a
   two-thread trace (user thread + progress thread).
2. Runs the automated timeline analyses of §4.1 — the contention detector
   finds the BlockingProgress-lock overlap exactly like the paper's Fig 8.
3. Re-runs with the second (incoming) queue and shows the contention gone
   (Fig 9), plus the Isend-latency-vs-load curves (Fig 10).
4. Also derives the *modeled device timeline* from compiled HLO — the TPU
   adaptation where collective exposure is read from the schedule itself.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import time

import jax
import jax.numpy as jnp

from repro.comm.progress import ProgressEngine
from repro.core import analyses, timeline
from repro.core.collector import global_collector, reset_global_collector


def run_engine(mode: str, n_requests: int = 48):
    work = jax.jit(lambda x: (x @ x).sum())
    x = jnp.ones((1024, 1024), jnp.float32)
    jax.block_until_ready(work(x))
    reset_global_collector()
    eng = ProgressEngine(mode)
    reqs = []
    # staggered submission so the user thread keeps enqueueing while the
    # progress thread is mid-processing — the realistic steady state
    for i in range(n_requests):
        reqs.append(eng.submit(work, x))
        if i % 4 == 3:
            time.sleep(0.002)
    for r in reqs:
        r.wait()
    eng.shutdown()
    return global_collector().drain()


def main():
    print("== one shared queue (pre-fix ExaMPI) ==")
    ev_old = run_engine("shared")
    findings = analyses.contention(ev_old, name_filter="BlockingProgress")
    print(analyses.report(findings, limit=5))
    isend_old = [e.duration / 1e3 for e in ev_old if e.name == "MPI_Isend"]
    print(f"MPI_Isend mean {sum(isend_old)/len(isend_old):.1f} us "
          f"max {max(isend_old):.1f} us over {len(isend_old)} calls")
    timeline.save_trace(timeline.to_chrome_trace(
        ev_old, thread_names={0: "user thread", 1: "progress thread"}),
        "/tmp/timeline_shared_queue.json")

    print("\n== second incoming queue (the fix) ==")
    ev_new = run_engine("incoming")
    findings_new = analyses.contention(ev_new, name_filter="BlockingProgress")
    print(analyses.report(findings_new, limit=5))
    isend_new = [e.duration / 1e3 for e in ev_new if e.name == "MPI_Isend"]
    print(f"MPI_Isend mean {sum(isend_new)/len(isend_new):.1f} us "
          f"max {max(isend_new):.1f} us")
    timeline.save_trace(timeline.to_chrome_trace(
        ev_new, thread_names={0: "user thread", 1: "progress thread"}),
        "/tmp/timeline_incoming_queue.json")

    print("\ntraces: /tmp/timeline_shared_queue.json, "
          "/tmp/timeline_incoming_queue.json (chrome://tracing)")

    print("\n== modeled device timeline from compiled HLO (TPU adaptation) ==")
    from repro.core import device_timeline as DT
    from jax.sharding import PartitionSpec as P
    from repro.core.compat import make_mesh
    mesh = make_mesh((1,), ("model",))

    def tp_layer(x, w):
        y = jnp.einsum("bd,df->bf", x, w)
        return jax.lax.psum(y, "model")

    from repro.core.compat import shard_map
    f = shard_map(tp_layer, mesh=mesh,
                  in_specs=(P(None, None), P(None, "model")),
                  out_specs=P(None, None))
    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 256), jnp.bfloat16),
        jax.ShapeDtypeStruct((256, 512), jnp.bfloat16)).compile().as_text()
    segs = DT.extract_schedule(txt)
    rep = DT.serialization_report(segs)
    print(rep.summary())


if __name__ == "__main__":
    main()
