"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_e2e.py --preset tiny    # CI/CPU
    PYTHONPATH=src python examples/train_e2e.py --preset 100m    # real run

Wraps repro.launch.train with two presets:
  tiny — ~4M params, 300 steps, finishes on 1 CPU core in minutes and
         shows the loss dropping on the structured synthetic stream.
  100m — ~100M params (d_model 768, 12 layers), few hundred steps;
         sized for a single accelerator host.
Both checkpoint every 50 steps and resume with --resume.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.preset == "tiny":
        steps = args.steps or 300
        argv = ["--arch", "yi-6b", "--preset", "smoke",
                "--steps", str(steps), "--batch", "8", "--seq", "128",
                "--d-model", "128", "--layers", "4",
                "--ckpt-dir", "/tmp/e2e_tiny", "--ckpt-every", "50",
                "--lr", "1e-3"]
    else:
        steps = args.steps or 300
        argv = ["--arch", "yi-6b", "--preset", "smoke",
                "--steps", str(steps), "--batch", "8", "--seq", "512",
                "--d-model", "768", "--layers", "12",
                "--ckpt-dir", "/tmp/e2e_100m", "--ckpt-every", "50",
                "--lr", "3e-4"]
    if args.resume:
        argv.append("--resume")
    losses = train.main(argv)
    assert losses[-1] == losses[-1], "loss is NaN"
    print(f"\ne2e {args.preset}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")


if __name__ == "__main__":
    main()
