"""Record once, replay everywhere (the trace subsystem), end to end.

    PYTHONPATH=src:. python examples/replay_tour.py

1. Records a *live* comm-layer run — ring all-gather + psum under
   shard_map on 8 host devices — through :func:`repro.trace.record_collectives`:
   every collective the program dispatches is decomposed into p2p
   messages, matched, and appended to a JSONL trace.
2. Replays that single trace offline under all three engine modes (no
   JAX, no re-execution) and shows the live detectors running on the
   replayed counter events.
3. Diffs the what-if replays against the healthy baseline with the trace
   differ — the regression primitive: the seeded-defect engines are
   flagged, the healthy engine diffs clean.
4. Feeds the replayed match latency into the roofline / modeled device
   timeline (method-2 counters on the modeled timeline) and exports the
   replay as a chrome trace with one lane per rank.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

TRACE = "/tmp/replay_tour_trace.jsonl"


def record_live_run():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comm import collectives
    from repro.comm.ring import ring_all_gather
    from repro.core.compat import make_mesh, shard_map
    from repro.core.counters import CounterRegistry
    from repro.trace import record_collectives

    n = min(8, len(jax.devices()))
    print(f"== 1. record a live comm-layer run ({n} host devices) ==")
    reg = CounterRegistry()
    with record_collectives(TRACE, mode="binned", registry=reg,
                            meta={"example": "replay_tour"}) as fab:
        mesh = make_mesh((n,), ("r",))
        x = jnp.arange(n * 4 * 2, dtype=jnp.float32).reshape(n * 4, 2)
        out = jax.jit(shard_map(
            lambda s: ring_all_gather(s, "r"),
            mesh=mesh, in_specs=P("r", None), out_specs=P("r", None)))(x)
        jax.block_until_ready(out)
        y = jnp.ones((n, 4), jnp.float32)
        out2 = jax.jit(shard_map(
            lambda s: collectives.psum(s, "r"),
            mesh=mesh, in_specs=P("r", None), out_specs=P(None, None)))(y)
        jax.block_until_ready(out2)
        # a many-outstanding-receives burst (the paper's Fig. 10 load) so
        # the linear-PRQ what-if replay below has depth to regress on
        fab.phase("burst", rank=0, outstanding=128)
        eng = fab.engine(0)
        for t in range(128):
            eng.post_recv(src=1, tag=10_000 + t)
        for t in reversed(range(128)):
            eng.arrive(src=1, tag=10_000 + t)

    from repro.trace import read_trace
    header, records = read_trace(TRACE)
    phases = [r for r in records if r["t"] == "phase"]
    ops = [r for r in records if r["t"] in ("post", "arr")]
    print(f"recorded {len(ops)} engine ops across {len(phases)} phases "
          f"(schema v{header['schema']}): {TRACE}")
    print("phase labels:", sorted({p["label"] for p in phases}), "\n")
    return header, records


def replay_everywhere(source):
    from repro.core import analyses
    from repro.trace import replay

    print("== 2. replay offline under every engine mode ==")
    replays = {}
    for mode in ("fifo", "linear", "leaky_umq"):
        res = replay(source, mode=mode)
        replays[mode] = res
        tot = res.totals()
        depth = tot.get("match.prq.traversal_depth")
        flags = sorted({f.kind for f in analyses.analyze_all(res.events)
                        if f.kind in ("long_traversal", "umq_flood")})
        print(f"mode={mode:10s}: ops replayed={len(res.matches)}, "
              f"divergences={len(res.divergences)}, "
              f"depth_mean={depth.mean if depth else 0:.2f}, "
              f"detector flags={flags}")
    print("(divergences=0 everywhere: the defects change cost, never "
          "matching — what-if replay is sound)\n")
    return replays


def diff_replays(replays):
    from repro.trace import diff

    print("== 3. trace differ vs the healthy baseline ==")
    base = replays["fifo"]
    for mode in ("linear", "leaky_umq"):
        d = diff(base, replays[mode])
        # the live-run workload is small, so use gentle thresholds here;
        # benchmarks/replay_sweep.py gates the full-size defaults
        flags = d.flags(depth_factor=2.0, depth_mean=2.0,
                        min_depth_samples=8, umq_factor=2.0, umq_len=4.0)
        print(f"fifo -> {mode}:")
        for f in flags[:3]:
            print("   " + str(f))
        if not flags:
            print("   (clean)")
    print()


def model_tie_in(replays):
    from repro.core import timeline
    from repro.core.device_timeline import (Segment, overlay_match_lane,
                                            to_events)
    from repro.core.roofline import Roofline, match_seconds

    print("== 4. measured match latency on the modeled timeline ==")
    tot = replays["linear"].totals()
    match_s = match_seconds(tot)
    roof = Roofline(flops=1e12, hbm_bytes=1e9, wire_bytes=4e8, n_chips=8,
                    match_s=match_s)
    print(f"roofline with measured match term: {roof.summary()}")

    # a toy modeled schedule: compute / collective / compute
    segments = [Segment("matmul", "compute", 2e-3),
                Segment("all-gather", "collective", 1e-3),
                Segment("matmul", "compute", 2e-3)]
    events = to_events(segments)
    lane = overlay_match_lane(events, tot)
    print(f"match lane: {len(lane)} event(s), "
          f"{sum(e.duration for e in lane) / 1e6:.3f} ms modeled on tid 2")

    replay_trace = "/tmp/replay_tour_replay.json"
    per_rank = replays["fifo"].events
    timeline.save_trace(timeline.to_chrome_trace(per_rank), replay_trace)
    print(f"replayed counter timeline (one lane per rank): {replay_trace} "
          f"(chrome://tracing)\n")


def main():
    source = record_live_run()
    replays = replay_everywhere(source)
    diff_replays(replays)
    model_tie_in(replays)
    print("tour complete — benchmarks/replay_sweep.py is the acceptance "
          "gate; README.md documents the record-once/replay-everywhere "
          "workflow")


if __name__ == "__main__":
    main()
