"""Live telemetry, end to end: watch a defect surface *mid-run*.

    PYTHONPATH=src:. python examples/telemetry_tour.py

Everything else in this repo is post-hoc — run, then read the profile.
This tour runs the ``unexpected_storm`` scenario with the leaky-UMQ
defect seeded, with a :class:`TelemetryBridge` polling the run's counter
registry from its own daemon thread and an HTTP/SSE endpoint serving the
stream. A client thread polls ``/findings`` over plain HTTP the whole
time — and sees the ``umq_flood`` detector fire while the workload is
still executing, not in the post-mortem:

1. delta frames stream to an in-process ring + a JSONL file while the
   storm drives the fabric (throttled, so the run spans many polls);
2. the ``/findings`` poller reports the flood the moment the cumulative
   UMQ stats cross the detector thresholds;
3. at the end, the bridge's cumulative lanes reproduce exactly the
   queue statistics a bridged-off run computes — streaming changed
   *when* the deltas were folded, never what they sum to.
"""
import json
import os
import random
import sys
import threading
import time
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main():
    from repro.telemetry import (JsonlSink, TelemetryBridge,
                                 TelemetryServer, read_jsonl)
    from repro.workloads import get
    from repro.workloads.bench import build_fabric

    sc = get("unexpected_storm")
    p = sc.params("smoke")

    bridge = TelemetryBridge(period_s=0.01, session="telemetry_tour")
    sink_path = os.path.join(os.path.dirname(__file__), "..", "results",
                             "telemetry_tour.jsonl")
    os.makedirs(os.path.dirname(sink_path), exist_ok=True)
    bridge.subscribe(JsonlSink(sink_path))
    server = TelemetryServer(bridge).start()
    bridge.start()
    print(f"telemetry up: {server.url}  (endpoints: /metrics /stream "
          f"/findings)\n")

    fab = build_fabric(sc, "leaky_umq")
    bridge.watch(fab.reg, name="storm")

    done = threading.Event()
    seen_at = {}

    def watch_findings():
        # a plain-HTTP client, like a dashboard would be
        while not done.is_set():
            with urllib.request.urlopen(server.url + "/findings",
                                        timeout=2) as r:
                for f in json.loads(r.read()):
                    key = (f["kind"], f.get("pid"))
                    if key not in seen_at:
                        seen_at[key] = time.perf_counter()
                        state = ("MID-RUN" if not done.is_set()
                                 else "post-run")
                        print(f"  [{state}] /findings: [{f['kind']}] "
                              f"pid {f.get('pid')} — {f['message']}")
            time.sleep(0.02)

    watcher = threading.Thread(target=watch_findings, daemon=True)
    watcher.start()

    print(f"driving unexpected_storm (leaky_umq, params {p}) ...")
    rng = random.Random(0)
    t0 = time.perf_counter()
    # throttle the drive so the storm spans many poll periods — a real
    # workload has compute between messages; sleep stands in for it
    for round_ in range(6):
        sc.drive(fab, rng, {**p, "rounds": 1})
        time.sleep(0.05)
    wall = time.perf_counter() - t0
    done.set()
    watcher.join()
    bridge.stop()

    floods = [k for k in seen_at if k[0] == "umq_flood"]
    live = [k for k in floods if seen_at[k] < t0 + wall]
    print(f"\nworkload ran {wall * 1e3:.0f} ms; umq_flood seen on "
          f"{len(floods)} rank(s), {len(live)} of them before the run "
          "finished")

    lanes = bridge.unwatch("storm")
    total = sum(per["match.umq.length"].count for per in lanes.values())
    print(f"cumulative lanes: {len(lanes)} ranks, "
          f"{total} UMQ-length samples, "
          f"{bridge.deltas_total} deltas over {bridge.polls} polls "
          f"(drop-free: {fab.reg.drain_stats()['pending']} pending)")

    server.stop()
    bridge.close()
    frames = read_jsonl(sink_path)
    kinds = {}
    for f in frames:
        kinds[f["t"]] = kinds.get(f["t"], 0) + 1
    print(f"JSONL sink: {len(frames)} frames {kinds} -> {sink_path}")


if __name__ == "__main__":
    main()
