"""Message-matching profiling (paper method 2), end to end.

    PYTHONPATH=src:. python examples/matching_tour.py

1. Shows the two-queue matching engine's semantics: envelope matching
   with MPI wildcards, per-envelope FIFO, expected vs unexpected paths.
2. Routes the real comm layer (ring collectives + halo permutes under
   shard_map on 8 host devices) through a matching Fabric and snapshots
   the counters into Event records — rendered as a GraphFrame tree and a
   chrome trace, the same viewers as method 1.
3. Seeds the paper-style defects (linear PRQ search, leaky UMQ) and shows
   ``analyze_all`` flagging exactly the defective engines.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def wildcard_demo():
    from repro.core.counters import CounterRegistry
    from repro.match import ANY_SOURCE, ANY_TAG, MatchEngine

    print("== matching semantics ==")
    eng = MatchEngine(mode="binned", registry=CounterRegistry())
    r_wild = eng.post_recv(src=ANY_SOURCE, tag=ANY_TAG)   # posted first
    r_spec = eng.post_recv(src=3, tag=7)
    eng.arrive(src=3, tag=7)       # matches the *earlier posted* wildcard
    print(f"first arrival -> wildcard recv completed: {r_wild.completed}, "
          f"specific still pending: {not r_spec.completed}")
    eng.arrive(src=3, tag=7)       # now the specific recv
    print(f"second arrival -> specific recv completed: {r_spec.completed}")
    eng.arrive(src=5, tag=9)       # nothing posted: unexpected path
    r_late = eng.post_recv(src=5, tag=9)
    print(f"late recv pulled the unexpected message: {r_late.completed}\n")


def comm_layer_tour():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comm import collectives
    from repro.comm.ring import ring_all_gather
    from repro.core import timeline
    from repro.core.compat import make_mesh, shard_map
    from repro.core.counters import CounterRegistry
    from repro.core.graphframe import GraphFrame
    from repro.match import Fabric

    n = min(8, len(jax.devices()))   # honor a user-preset XLA_FLAGS
    print(f"== comm layer through the matching engine ({n} host devices) ==")
    if n == 1:
        print("(single device: rings have no steps — set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 for the "
              "full tour)")
    reg = CounterRegistry()
    collectives.configure_matching(Fabric(mode="binned", registry=reg))
    try:
        mesh = make_mesh((n,), ("r",))
        x = jnp.arange(n * 4 * 2, dtype=jnp.float32).reshape(n * 4, 2)
        out = jax.jit(shard_map(
            lambda s: ring_all_gather(s, "r"),
            mesh=mesh, in_specs=P("r", None), out_specs=P("r", None)))(x)
        jax.block_until_ready(out)
        y = jnp.ones((n, 4), jnp.float32)
        out2 = jax.jit(shard_map(
            lambda s: collectives.psum(s, "r"),
            mesh=mesh, in_specs=P("r", None), out_specs=P(None, None)))(y)
        jax.block_until_ready(out2)
    finally:
        collectives.configure_matching(None)

    from repro.core.counters import counter_stats

    events = reg.snapshot_events()
    print("counter stats from the ring_all_gather + psum dispatches:")
    for name, st in sorted(counter_stats(events).items()):
        line = f"  {name:30s} count={st.count:<6d} total={st.total:<10.0f}"
        if st.kind == "histogram":
            line += f" mean={st.mean:.2f} max={st.vmax:.0f}"
        print(line)
    print("counter tree (GraphFrame over snapshot events):")
    gf = GraphFrame.from_events(events)
    print(gf.tree(metric="count", fmt="{:.0f}"))
    path = "/tmp/matching_counters.json"
    timeline.save_trace(timeline.to_chrome_trace(events), path)
    print(f"counter snapshot trace: {path} (chrome://tracing)\n")


def defect_tour():
    from repro.core import analyses
    from repro.core.counters import CounterRegistry
    from repro.match import Fabric

    print("== seeded defects vs the detectors ==")
    for mode in ("binned", "linear", "leaky_umq"):
        reg = CounterRegistry()
        fab = Fabric(mode=mode, registry=reg)
        for r in range(30):
            fab.all_reduce(16, nbytes=1 << 20)
            eng = fab.engine(0)
            for t in range(512):
                eng.post_recv(src=1, tag=1000 + t)
            for t in reversed(range(512)):
                eng.arrive(src=1, tag=1000 + t)
        findings = [f for f in analyses.analyze_all(reg.snapshot_events())
                    if f.kind in ("long_traversal", "umq_flood")]
        label = "fixed" if mode == "binned" else "defect"
        print(f"mode={mode:10s} ({label}): "
              f"{analyses.report(findings, limit=2)}")
    print()


def main():
    wildcard_demo()
    comm_layer_tour()
    defect_tour()
    print("tour complete — see benchmarks/matching_sweep.py for the "
          "queue-depth figures and README.md for the method mapping")


if __name__ == "__main__":
    main()
